"""Baseline external-resource systems the paper compares against (§6.1).

All baselines expose the same ``submit / trajectory_start / trajectory_end
/ run`` surface as :class:`~repro.core.tangram.Tangram`, so the workload
generators drive either system unchanged.

* :class:`TrajectoryStaticCpuSystem` — the Kubernetes baseline: one pod
  per trajectory (0.5-CPU request, 4-CPU limit), pod creation through a
  serialized control plane, CFS fair-sharing when demand exceeds cores,
  resources held for the trajectory's whole lifetime.
* :class:`StaticGpuServiceSystem`  — the SGLang baseline: each service
  pinned to dedicated GPUs at fixed TP, per-service FIFO replicas, no
  cross-service sharing.
* :class:`ServerlessLlmSystem`     — MaaS baseline: shared GPU pool,
  fixed DoP, cold-start model loading (slower than EOE restore), no
  elastic reallocation, timeout failures under pressure.
* :class:`UnmanagedApiSystem`      — DeepSearch baseline: clients fire
  API calls directly; rate-limit violations cause failures and <=3
  retries with a 600 s timeout.

Additionally, two *policy-level* baselines implement the orchestrator's
:class:`~repro.core.orchestrator.SchedulingPolicy` protocol, so ablations
can swap the scheduling algorithm while keeping Tangram's managers,
lifecycle, and telemetry:

* :class:`FcfsPolicy`      — strict FCFS at minimum units, no elasticity;
* :class:`StaticDopPolicy` — every scalable action pinned to one fixed
  DoP (the SGLang-style "static TP" discipline) on a shared pool.

Multi-tenant fairness ablations compose orthogonally: the *queueing*
ablation is ``Orchestrator(fair_share=None)`` (plain cross-task FCFS
partitions — the pre-fairness path), and the *allocation* ablation is
``FcfsPolicy`` under a fair-share orchestrator (weighted ordering, but
no elastic/weighted allocation).  ``bench_scheduler --suite fairness``
measures both against the full WFQ + fairness-aware ElasticScheduler
stack.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.action import Action, ActionState
from repro.core.scheduler import Decision, ScheduleResult
from repro.core.simulator import EventLoop, Future
from repro.core.telemetry import ActionRecord, Telemetry


# ---------------------------------------------------------------------------
# Policy-level baselines (SchedulingPolicy protocol)
# ---------------------------------------------------------------------------


class FcfsPolicy:
    """Strict FCFS at least-required units — the no-elasticity ablation."""

    def __init__(self, candidate_limit: int = 128) -> None:
        self.candidate_limit = candidate_limit

    def arrange(
        self,
        candidates: Sequence[Action],
        remaining: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, object],
        now: float,
    ) -> ScheduleResult:
        return ScheduleResult(
            decisions=[Decision(a, a.min_cost()) for a in candidates]
        )

    def schedule(
        self,
        waiting: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, object],
        now: float,
    ) -> ScheduleResult:
        from repro.core.orchestrator import candidate_window

        candidates = candidate_window(waiting, managers, self.candidate_limit)
        return self.arrange(
            candidates, list(waiting[len(candidates) :]), executing, managers, now
        )


class StaticDopPolicy(FcfsPolicy):
    """FCFS with every scalable action pinned at a fixed DoP (static TP).

    The admission window still opens at min units, so an action whose
    static DoP exceeds what is currently free simply fails allocation
    and retries — mirroring the queueing behaviour of a fixed-TP
    deployment on a shared pool.
    """

    def __init__(self, dop: int = 4, candidate_limit: int = 128) -> None:
        super().__init__(candidate_limit)
        self.dop = dop

    def arrange(
        self,
        candidates: Sequence[Action],
        remaining: Sequence[Action],
        executing: Sequence[Action],
        managers: Dict[str, object],
        now: float,
    ) -> ScheduleResult:
        decisions = []
        for a in candidates:
            units = a.min_cost()
            if a.key_resource is not None:
                feasible = a.key_units()
                units[a.key_resource] = max(
                    (u for u in feasible if u <= self.dop), default=feasible[0]
                )
            decisions.append(Decision(a, units))
        return ScheduleResult(decisions=decisions)


class _BaseSystem:
    def __init__(self, loop: Optional[EventLoop] = None) -> None:
        self.loop = loop or EventLoop()
        self.telemetry = Telemetry()
        self._futures: Dict[int, Future] = {}

    @property
    def now(self) -> float:
        return self.loop.clock.now()

    def run(self, until: Optional[float] = None) -> float:
        return self.loop.run(until=until)

    def trajectory_start(self, trajectory_id: str, metadata: Optional[dict] = None) -> None:
        pass

    def trajectory_end(self, trajectory_id: str) -> None:
        pass

    def _finish(self, action: Action, units: Dict[str, int], failed: bool = False, retries: int = 0) -> None:
        action.state = ActionState.FAILED if failed else ActionState.DONE
        self.telemetry.record(
            ActionRecord(
                name=action.name,
                task_id=action.task_id,
                trajectory_id=action.trajectory_id,
                submit=action.submit_time,
                start=action.start_time,
                finish=action.finish_time,
                sys_overhead=action.sys_overhead,
                units=units,
                failed=failed,
                retries=retries,
            )
        )
        fut = self._futures.pop(action.uid, None)
        if fut is not None:
            fut.set_result(not failed)


# ---------------------------------------------------------------------------
# Kubernetes-style trajectory-level CPU baseline
# ---------------------------------------------------------------------------


@dataclass
class _CfsJob:
    action: Action
    demand: float  # cores desired
    remaining: float  # core-seconds of work left
    rate: float = 0.0
    event: object = None


class TrajectoryStaticCpuSystem(_BaseSystem):
    """Pod-per-trajectory with CFS fair sharing (paper §6.1 AI-coding baseline)."""

    def __init__(
        self,
        total_cores: int,
        loop: Optional[EventLoop] = None,
        pod_request: float = 0.5,
        pod_limit: float = 4.0,
        pod_create_base_s: float = 2.0,
        control_plane_rate: float = 8.0,  # pod creations per second
        admission_timeout_s: float = 600.0,
    ) -> None:
        super().__init__(loop)
        self.total_cores = total_cores
        self.pod_request = pod_request
        self.pod_limit = pod_limit
        self.pod_create_base_s = pod_create_base_s
        self.control_plane_rate = control_plane_rate
        self.admission_timeout_s = admission_timeout_s
        self._reserved = 0.0
        self._pods_ready: Dict[str, float] = {}  # traj -> ready time
        self._cp_free_at = 0.0  # control plane serialization
        self._jobs: List[_CfsJob] = []

    # -- trajectory lifecycle -----------------------------------------------
    def trajectory_start(self, trajectory_id: str, metadata: Optional[dict] = None) -> None:
        # admission: wait until reservation fits, then pay serialized
        # control-plane latency.
        t = self.now
        self._cp_free_at = max(self._cp_free_at, t) + 1.0 / self.control_plane_rate
        ready = self._cp_free_at + self.pod_create_base_s
        # reservation pressure: if the cluster is fully reserved the pod
        # queues behind running trajectories (modeled as proportional delay).
        over = max(0.0, (self._reserved + self.pod_request) - self.total_cores)
        if over > 0:
            ready += over / self.pod_request * 1.0  # each blocked pod ~1 s retry loop
        self._reserved += self.pod_request
        self._pods_ready[trajectory_id] = ready

    def trajectory_end(self, trajectory_id: str) -> None:
        if trajectory_id in self._pods_ready:
            del self._pods_ready[trajectory_id]
            self._reserved -= self.pod_request

    # -- CFS fluid model ------------------------------------------------------
    def _rebalance(self) -> None:
        now = self.now
        # settle progress at old rates
        for j in self._jobs:
            pass  # progress is settled in _advance before mutation
        demand = sum(j.demand for j in self._jobs)
        scale = min(1.0, self.total_cores / demand) if demand > 0 else 1.0
        for j in self._jobs:
            j.rate = j.demand * scale
            if j.event is not None:
                self.loop.cancel(j.event)
            eta = j.remaining / j.rate if j.rate > 0 else math.inf
            j.event = self.loop.call_after(eta, lambda jj=j: self._job_done(jj))
            j.action.finish_time = now + eta

    def _advance(self) -> None:
        """Settle remaining work at current rates up to now."""
        now = self.now
        for j in self._jobs:
            elapsed = now - getattr(j, "_last_t", j.action.start_time)
            j.remaining = max(0.0, j.remaining - elapsed * j.rate)
            j._last_t = now  # type: ignore[attr-defined]

    def submit(self, action: Action, delay: float = 0.0) -> Future:
        fut = Future()
        self._futures[action.uid] = fut

        def _arrive() -> None:
            action.submit_time = self.now
            ready = self._pods_ready.get(action.trajectory_id, self.now)
            wait = max(0.0, ready - self.now)
            if wait > self.admission_timeout_s:
                action.start_time = self.now
                action.finish_time = self.now + self.admission_timeout_s
                self._finish(action, {}, failed=True)
                return
            self.loop.call_after(wait, lambda: self._start(action))

        self.loop.call_after(delay, _arrive)
        return fut

    def _start(self, action: Action) -> None:
        self._advance()
        action.start_time = self.now
        # demand capped by the pod limit; elasticity beyond the limit is lost
        feasible = action.key_units()
        demand = float(min(self.pod_limit, max(1, feasible[0])))
        base = action.base_duration
        if base is None and action.duration_sampler is not None:
            base = action.duration_sampler(1)
        work = float(base if base is not None else 1.0)  # core-seconds at 1 core
        if action.elasticity is not None and demand > 1:
            work = base / action.elasticity.speedup(int(demand)) * demand
        job = _CfsJob(action=action, demand=demand, remaining=work)
        job._last_t = self.now  # type: ignore[attr-defined]
        self._jobs.append(job)
        self._rebalance()

    def _job_done(self, job: _CfsJob) -> None:
        self._advance()
        if job not in self._jobs:
            return
        if job.remaining > 1e-9:  # rates changed; re-arm
            self._rebalance()
            return
        self._jobs.remove(job)
        job.action.finish_time = self.now
        self._finish(job.action, {"cpu": int(job.demand)})
        self._rebalance()


# ---------------------------------------------------------------------------
# SGLang-style static GPU services
# ---------------------------------------------------------------------------


class StaticGpuServiceSystem(_BaseSystem):
    """Each service pinned to dedicated GPUs at fixed TP; FIFO per service."""

    def __init__(
        self,
        services: Dict[str, int],  # service -> replica count
        tp: int = 4,
        loop: Optional[EventLoop] = None,
    ) -> None:
        super().__init__(loop)
        self.tp = tp
        self._free: Dict[str, int] = dict(services)
        self._queues: Dict[str, List[Action]] = {s: [] for s in services}
        self.total_gpus = sum(services.values()) * tp

    def submit(self, action: Action, delay: float = 0.0) -> Future:
        fut = Future()
        self._futures[action.uid] = fut

        def _arrive() -> None:
            action.submit_time = self.now
            svc = action.service or "default"
            if svc not in self._queues:
                raise KeyError(f"service {svc!r} not deployed in static baseline")
            self._queues[svc].append(action)
            self._drain(svc)

        self.loop.call_after(delay, _arrive)
        return fut

    def _drain(self, svc: str) -> None:
        while self._queues[svc] and self._free[svc] > 0:
            action = self._queues[svc].pop(0)
            self._free[svc] -= 1
            action.start_time = self.now
            dur = self._dur(action)
            action.finish_time = self.now + dur
            self.loop.call_at(
                action.finish_time, lambda a=action, s=svc: self._done(a, s)
            )

    def _dur(self, action: Action) -> float:
        if action.duration_sampler is not None:
            return action.duration_sampler(self.tp)
        feasible = action.key_units()
        m = max((u for u in feasible if u <= self.tp), default=feasible[0])
        try:
            return action.get_dur(m)
        except ValueError:
            return action.get_dur()

    def _done(self, action: Action, svc: str) -> None:
        self._free[svc] += 1
        self._finish(action, {"gpu": self.tp})
        self._drain(svc)


# ---------------------------------------------------------------------------
# ServerlessLLM-style MaaS
# ---------------------------------------------------------------------------


class ServerlessLlmSystem(_BaseSystem):
    """Shared pool, fixed DoP, cold-start loads, no elastic reallocation."""

    def __init__(
        self,
        total_gpus: int,
        service_state_gb: Dict[str, float],
        dop: int = 4,
        load_bw_gbps: float = 16.0,  # slower than EOE restore (no live snapshot)
        timeout_s: float = 600.0,
        loop: Optional[EventLoop] = None,
    ) -> None:
        super().__init__(loop)
        self.dop = dop
        self.slots = total_gpus // dop
        self.state_gb = service_state_gb
        self.load_bw = load_bw_gbps
        self.timeout_s = timeout_s
        self._slot_model: List[Optional[str]] = [None] * self.slots
        self._slot_busy: List[bool] = [False] * self.slots
        self._slot_lru: List[float] = [0.0] * self.slots
        self._queue: List[Action] = []

    def submit(self, action: Action, delay: float = 0.0) -> Future:
        fut = Future()
        self._futures[action.uid] = fut

        def _arrive() -> None:
            action.submit_time = self.now
            self._queue.append(action)
            self._drain()

        self.loop.call_after(delay, _arrive)
        return fut

    def _drain(self) -> None:
        progressed = True
        while progressed and self._queue:
            progressed = False
            action = self._queue[0]
            if self.now - action.submit_time > self.timeout_s:
                self._queue.pop(0)
                action.start_time = action.submit_time
                action.finish_time = action.submit_time + self.timeout_s
                self._finish(action, {}, failed=True)
                progressed = True
                continue
            svc = action.service or "default"
            slot = self._pick_slot(svc)
            if slot is None:
                break
            self._queue.pop(0)
            self._slot_busy[slot] = True
            cold = self._slot_model[slot] != svc
            overhead = (
                self.state_gb.get(svc, 40.0) / self.load_bw if cold else 0.0
            )
            self._slot_model[slot] = svc
            self._slot_lru[slot] = self.now
            action.start_time = self.now
            action.sys_overhead = overhead
            dur = self._dur(action)
            action.finish_time = self.now + overhead + dur
            self.loop.call_at(action.finish_time, lambda a=action, s=slot: self._done(a, s))
            progressed = True
        # timeout sweep for queued requests
        if self._queue:
            head = self._queue[0]
            self.loop.call_after(
                max(0.0, head.submit_time + self.timeout_s - self.now) + 1e-6,
                self._drain,
            )

    def _pick_slot(self, svc: str) -> Optional[int]:
        idle = [i for i in range(self.slots) if not self._slot_busy[i]]
        if not idle:
            return None
        warm = [i for i in idle if self._slot_model[i] == svc]
        if warm:
            return warm[0]
        empty = [i for i in idle if self._slot_model[i] is None]
        if empty:
            return empty[0]
        return min(idle, key=lambda i: self._slot_lru[i])  # LRU cold replace

    def _dur(self, action: Action) -> float:
        if action.duration_sampler is not None:
            return action.duration_sampler(self.dop)
        feasible = action.key_units()
        m = max((u for u in feasible if u <= self.dop), default=feasible[0])
        try:
            return action.get_dur(m)
        except ValueError:
            return action.get_dur()

    def _done(self, action: Action, slot: int) -> None:
        self._slot_busy[slot] = False
        self._slot_lru[slot] = self.now
        self._finish(action, {"gpu": self.dop})
        self._drain()


# ---------------------------------------------------------------------------
# Unmanaged API calls (DeepSearch baseline)
# ---------------------------------------------------------------------------


class UnmanagedApiSystem(_BaseSystem):
    """Clients call APIs directly; overload causes failures and retries."""

    def __init__(
        self,
        rate_limit: int = 64,  # concurrent calls tolerated by the provider
        retry_limit: int = 3,
        timeout_s: float = 600.0,
        backoff_s: float = 30.0,
        seed: int = 0,
        loop: Optional[EventLoop] = None,
    ) -> None:
        super().__init__(loop)
        self.rate_limit = rate_limit
        self.retry_limit = retry_limit
        self.timeout_s = timeout_s
        self.backoff_s = backoff_s
        self._rng = random.Random(seed)
        self._in_flight = 0

    def submit(self, action: Action, delay: float = 0.0) -> Future:
        fut = Future()
        self._futures[action.uid] = fut
        self.loop.call_after(delay, lambda: self._attempt(action, 0, None))
        return fut

    def _attempt(self, action: Action, tries: int, first_submit: Optional[float]) -> None:
        if first_submit is None:
            first_submit = self.now
            action.submit_time = self.now
        self._in_flight += 1
        over = max(0.0, (self._in_flight - self.rate_limit) / max(1, self.rate_limit))
        p_fail = min(0.9, over)  # throttling probability grows with overload
        dur = (
            action.duration_sampler(1)
            if action.duration_sampler is not None
            else (action.base_duration or 1.0)
        )
        if self._rng.random() < p_fail:
            # throttled: wastes a timeout slice, then retries
            wasted = min(self.timeout_s, self.backoff_s * (tries + 1))
            self.loop.call_after(
                wasted, lambda: self._retry(action, tries, first_submit)
            )
        else:
            self.loop.call_after(dur, lambda: self._ok(action, first_submit, tries))

    def _retry(self, action: Action, tries: int, first_submit: float) -> None:
        self._in_flight -= 1
        if tries + 1 >= self.retry_limit or self.now - first_submit > self.timeout_s:
            action.start_time = first_submit
            action.finish_time = self.now
            self._finish(action, {}, failed=True, retries=tries + 1)
            return
        self._attempt(action, tries + 1, first_submit)

    def _ok(self, action: Action, first_submit: float, tries: int) -> None:
        self._in_flight -= 1
        action.start_time = first_submit
        action.finish_time = self.now
        self._finish(action, {"api": 1}, retries=tries)
