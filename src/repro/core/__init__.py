"""ARL-Tangram core: action-level external-resource orchestration.

The paper's contribution, as a composable library:

* :mod:`repro.core.action`     — unified action formulation (§4.1)
* :mod:`repro.core.scheduler`  — elastic scheduling, Algorithms 1-2 (§4.2)
* :mod:`repro.core.dparrange`  — topology-agnostic DPArrange, Alg. 3-4 (App. B)
* :mod:`repro.core.managers`   — Basic / CPU(AOE) / GPU(EOE) managers (§5)
* :mod:`repro.core.orchestrator` — event-driven control plane: partitioned
  queues, incremental rounds, policies, action lifecycle
* :mod:`repro.core.shards`     — sharded plan/commit scheduling rounds
* :mod:`repro.core.wire`       — versioned wire codecs (plans/snapshots/
  sub-queues across process boundaries, no pickle)
* :mod:`repro.core.remote`     — out-of-process shard workers + transports
* :mod:`repro.core.tangram`    — the system facade (§3)
* :mod:`repro.core.baselines`  — k8s / SGLang / ServerlessLLM baselines (§6.1)
* :mod:`repro.core.simulator`  — discrete-event engine
"""

from repro.core.action import (
    Action,
    AmdahlElasticity,
    DurationHistory,
    Elasticity,
    LinearElasticity,
    ResourceRequest,
    TableElasticity,
    fixed,
    powers_of_two,
    ranged,
)
from repro.core.cluster import ClusterSpec, paper_testbed, tpu_reward_pool
from repro.core.dparrange import (
    BasicDPOperator,
    DPTask,
    GpuChunkDPOperator,
    TransitionTable,
    brute_force_arrange,
    dp_arrange,
    dp_arrange_prefixes,
    dp_arrange_ref,
)
from repro.core.baselines import FcfsPolicy, StaticDopPolicy
from repro.core.fairqueue import FairSharePolicy, PartitionQueue, TaskShard
from repro.core.managers import BasicResourceManager, CpuManager, GpuManager
from repro.core.managers.gpu import ChunkAllocator, ServiceSpec
from repro.core.orchestrator import (
    ActionCancelled,
    ActionError,
    ActionTimeout,
    Orchestrator,
    SchedulingPolicy,
)
from repro.core.remote import (
    LoopbackTransport,
    ProcessTransport,
    RemoteShardWorker,
    ShardTransport,
)
from repro.core.scheduler import ElasticScheduler
from repro.core.shards import PartitionPlan, RoundExecutor
from repro.core.simulator import EventLoop, SimClock
from repro.core.tangram import Tangram
from repro.core.telemetry import Telemetry

__all__ = [
    "Action",
    "ActionCancelled",
    "ActionError",
    "ActionTimeout",
    "AmdahlElasticity",
    "BasicDPOperator",
    "BasicResourceManager",
    "ChunkAllocator",
    "ClusterSpec",
    "CpuManager",
    "DPTask",
    "DurationHistory",
    "Elasticity",
    "ElasticScheduler",
    "EventLoop",
    "FairSharePolicy",
    "FcfsPolicy",
    "GpuChunkDPOperator",
    "GpuManager",
    "LinearElasticity",
    "LoopbackTransport",
    "Orchestrator",
    "PartitionPlan",
    "PartitionQueue",
    "ProcessTransport",
    "RemoteShardWorker",
    "ResourceRequest",
    "RoundExecutor",
    "SchedulingPolicy",
    "ServiceSpec",
    "ShardTransport",
    "SimClock",
    "StaticDopPolicy",
    "Tangram",
    "TableElasticity",
    "TaskShard",
    "Telemetry",
    "TransitionTable",
    "brute_force_arrange",
    "dp_arrange",
    "dp_arrange_prefixes",
    "dp_arrange_ref",
    "fixed",
    "paper_testbed",
    "powers_of_two",
    "ranged",
    "tpu_reward_pool",
]
