"""Unified action-level formulation (paper §4.1).

Every external-resource invocation in agentic RL is normalized into an
:class:`Action` carrying

* a **vectorized resource cost** ``C_i = (c_i0, ..., c_ik-1)`` — one
  :class:`ResourceRequest` per resource type the action touches.  Each
  dimension is not a scalar but a *constrained set* of feasible
  quantities (e.g. GPUs in ``{1, 2, 4, 8}``),
* an **elasticity model** ``dur(m) = T_ori / (E(m) * m)`` with
  ``0 < E(m) <= 1`` (paper Eq. 1), attached to a single *key elasticity
  resource* (paper assumption: one resource type dominates scaling), and
* a profiled **base duration** ``T_ori`` (duration with one unit of the
  key resource) where available; actions with unknown duration are still
  schedulable (they are simply never scaled, §4.2).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Resource requests
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ResourceRequest:
    """One dimension of the vectorized cost ``C_i``.

    ``units`` is the ordered set of feasible quantities for this resource
    (paper: "the c_{i,j} in C_i has a specific constraint, representing
    its all possible resource quantity").  A non-elastic request has a
    single feasible quantity.
    """

    rtype: str
    units: Tuple[int, ...]  # sorted ascending, all > 0

    def __post_init__(self) -> None:
        if not self.units:
            raise ValueError(f"{self.rtype}: empty feasible-unit set")
        if any(u <= 0 for u in self.units):
            raise ValueError(f"{self.rtype}: units must be positive")
        if tuple(sorted(self.units)) != tuple(self.units):
            object.__setattr__(self, "units", tuple(sorted(self.units)))

    @property
    def min_units(self) -> int:
        return self.units[0]

    @property
    def max_units(self) -> int:
        return self.units[-1]

    @property
    def elastic(self) -> bool:
        return len(self.units) > 1


def fixed(rtype: str, units: int = 1) -> ResourceRequest:
    return ResourceRequest(rtype, (units,))


def ranged(rtype: str, lo: int, hi: int, step: int = 1) -> ResourceRequest:
    return ResourceRequest(rtype, tuple(range(lo, hi + 1, step)))


def powers_of_two(rtype: str, lo: int = 1, hi: int = 8) -> ResourceRequest:
    units = tuple(1 << a for a in range(int(math.log2(lo)), int(math.log2(hi)) + 1))
    return ResourceRequest(rtype, units)


# ---------------------------------------------------------------------------
# Elasticity modelling (paper Eq. 1)
# ---------------------------------------------------------------------------


class Elasticity:
    """Mapping m -> E(m) in (0, 1]; E(1) == 1 by normalization."""

    def ratio(self, m: int) -> float:  # pragma: no cover - interface
        raise NotImplementedError

    def speedup(self, m: int) -> float:
        """Effective speedup over one unit: E(m) * m."""
        e = self.ratio(m)
        if not (0.0 < e <= 1.0 + 1e-9):
            raise ValueError(f"E({m}) = {e} outside (0, 1]")
        return e * m


@dataclass(frozen=True)
class AmdahlElasticity(Elasticity):
    """E(m) from Amdahl's law with serial fraction ``serial``.

    speedup(m) = 1 / (serial + (1 - serial)/m), E(m) = speedup(m)/m.
    Models parallel test execution (pytest -n) and TP inference, whose
    efficiency decays with DoP.
    """

    serial: float = 0.05

    def ratio(self, m: int) -> float:
        if m <= 0:
            raise ValueError("m must be positive")
        sp = 1.0 / (self.serial + (1.0 - self.serial) / m)
        return sp / m


@dataclass(frozen=True)
class TableElasticity(Elasticity):
    """Profiled E(m) table with geometric interpolation between knots."""

    table: Tuple[Tuple[int, float], ...]  # ((m, E(m)), ...) sorted by m

    def ratio(self, m: int) -> float:
        knots = self.table
        if m <= knots[0][0]:
            return knots[0][1]
        for (m0, e0), (m1, e1) in itertools.pairwise(knots):
            if m0 <= m <= m1:
                if m1 == m0:
                    return e1
                t = (m - m0) / (m1 - m0)
                return e0 * (e1 / e0) ** t
        return knots[-1][1]


@dataclass(frozen=True)
class LinearElasticity(Elasticity):
    """Perfectly elastic: E(m) == 1 (ideal batch-parallel work)."""

    def ratio(self, m: int) -> float:
        return 1.0


# ---------------------------------------------------------------------------
# Actions
# ---------------------------------------------------------------------------


class ActionState(Enum):
    PENDING = "pending"
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    TIMEOUT = "timeout"
    CANCELLED = "cancelled"


#: States from which an action can never leave (its future is resolved).
TERMINAL_STATES = frozenset(
    {ActionState.DONE, ActionState.FAILED, ActionState.TIMEOUT, ActionState.CANCELLED}
)


_ACTION_COUNTER = itertools.count()


@dataclass
class Action:
    """An atomic external-resource invocation (paper §2.4, §4.1)."""

    name: str
    cost: Dict[str, ResourceRequest]
    # --- elasticity (paper §4.1): single key elasticity resource ---
    key_resource: Optional[str] = None
    elasticity: Optional[Elasticity] = None
    base_duration: Optional[float] = None  # T_ori (1 unit of key resource)
    # --- provenance / multi-tenant fair share ---
    task_id: str = "task0"
    trajectory_id: str = "traj0"
    # fair-share weight override for THIS action; None defers to the
    # FairSharePolicy's per-task weight (tasks are the sharing tenant —
    # per-action overrides exist for e.g. latency-critical probes).
    weight: Optional[float] = None
    service: Optional[str] = None  # GPU manager: required service name
    # --- execution payload (live mode) / duration sampler (sim mode) ---
    fn: Optional[Callable[..., object]] = None
    duration_sampler: Optional[Callable[[int], float]] = None
    metadata: Dict[str, object] = field(default_factory=dict)
    # --- lifecycle policy (orchestrator-enforced) ---
    timeout_s: Optional[float] = None  # per-attempt deadline from (re)queueing
    max_retries: int = 0  # bounded re-queue-at-head retries after timeout

    # --- lifecycle bookkeeping (filled by the system) ---
    uid: int = field(default_factory=lambda: next(_ACTION_COUNTER))
    state: ActionState = ActionState.PENDING
    submit_time: float = math.nan
    start_time: float = math.nan
    finish_time: float = math.nan
    sys_overhead: float = 0.0
    attempts: int = 0  # completed (timed-out) attempts so far
    failure: Optional[str] = None  # terminal failure reason, if any
    allocation: Optional[object] = None  # set by the manager

    def __post_init__(self) -> None:
        if self.key_resource is not None and self.key_resource not in self.cost:
            raise ValueError(
                f"key resource {self.key_resource!r} not in cost vector "
                f"{sorted(self.cost)}"
            )
        if self.elasticity is not None and self.key_resource is None:
            raise ValueError("elasticity requires a key_resource")

    # -- paper Eq. 1 -------------------------------------------------------
    def get_dur(self, m: Optional[int] = None) -> float:
        """Estimated execution duration with ``m`` key-resource units.

        ``a.getDur(m) = T_ori / (E(m) * m)``.  For actions without a
        profiled duration this returns NaN — the scheduler treats such
        actions as non-scalable and uses historical averages for heap
        insertion (§4.2).
        """
        if self.base_duration is None:
            return math.nan
        if m is None or self.elasticity is None or self.key_resource is None:
            return self.base_duration
        req = self.cost[self.key_resource]
        if m not in req.units:
            raise ValueError(f"{m} not a feasible unit count for {self.name}: {req.units}")
        return self.base_duration / self.elasticity.speedup(m)

    @property
    def scalable(self) -> bool:
        """Scalable := elasticity known, key resource elastic, duration known."""
        return (
            self.elasticity is not None
            and self.key_resource is not None
            and self.cost[self.key_resource].elastic
            and self.base_duration is not None
        )

    def key_units(self) -> Tuple[int, ...]:
        if self.key_resource is None:
            return (1,)
        return self.cost[self.key_resource].units

    def min_cost(self) -> Dict[str, int]:
        return {r: req.min_units for r, req in self.cost.items()}

    # -- telemetry ---------------------------------------------------------
    @property
    def queue_duration(self) -> float:
        return self.start_time - self.submit_time

    @property
    def exec_duration(self) -> float:
        return self.finish_time - self.start_time

    @property
    def act(self) -> float:
        """Action completion time = queueing + execution (paper Eq. 2)."""
        return self.finish_time - self.submit_time

    def __repr__(self) -> str:  # concise for logs
        return (
            f"Action({self.name}#{self.uid} traj={self.trajectory_id} "
            f"state={self.state.value})"
        )


# ---------------------------------------------------------------------------
# Historical-average duration registry (paper §4.2: non-scalable actions'
# durations "approximated by historical averages")
# ---------------------------------------------------------------------------


class DurationHistory:
    """EWMA of observed execution durations keyed by action name."""

    def __init__(self, alpha: float = 0.3, default: float = 1.0) -> None:
        self._alpha = alpha
        self._default = default
        self._avg: Dict[str, float] = {}

    def observe(self, name: str, duration: float) -> None:
        prev = self._avg.get(name)
        self._avg[name] = (
            duration if prev is None else self._alpha * duration + (1 - self._alpha) * prev
        )

    def estimate(self, action: Action) -> float:
        if action.base_duration is not None:
            return action.base_duration
        return self._avg.get(action.name, self._default)
