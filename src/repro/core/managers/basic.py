"""Basic resource manager (paper §5.1).

For resources that cannot be scaled up — website quotas, request QPS
limits — supporting two consumption patterns:

* **concurrency-based**: bounds the maximum concurrent usage;
* **quota-based**: bounds total usage within a rolling period (tokens
  refilled every ``period_s`` of the governing clock).

Both prevent the contention / rate-limit violations that cause the
baseline's API failures and retries (§6.2, DeepSearch).
"""

from __future__ import annotations

import math
from typing import Optional

from repro.core.action import Action
from repro.core.cluster import ApiResourceSpec
from repro.core.managers.base import Allocation, ResourceManager
from repro.core.simulator import Clock, FrozenClock


class BasicResourceManager(ResourceManager):
    wire_impl = "api"

    def __init__(self, spec: ApiResourceSpec, clock: Clock) -> None:
        self.spec = spec
        self.mode = spec.mode
        self._clock = clock
        if self.mode == "concurrency":
            super().__init__(spec.name, spec.max_concurrency)
        elif self.mode == "quota":
            super().__init__(spec.name, spec.quota)
            self._period_start = clock.now()
            self._tokens = spec.quota
        else:
            raise ValueError(f"unknown mode {spec.mode!r}")

    # -- quota refill -------------------------------------------------------
    def _refill(self) -> None:
        now = self._clock.now()
        periods = math.floor((now - self._period_start) / self.spec.period_s)
        if periods > 0:
            self._period_start += periods * self.spec.period_s
            self._tokens = self.spec.quota

    @property
    def available(self) -> int:
        if self.mode == "quota":
            self._refill()
            # quota consumption is additionally bounded by concurrency of
            # in-flight requests only through tokens, so availability is
            # remaining tokens.
            return self._tokens
        return super().available

    # NOTE on the inherited dp_cache_key: it reads ``available``, which
    # for the quota mode re-runs _refill against the governing clock, so
    # the key reflects the token count AT THIS INSTANT — a refill
    # between rounds rotates the key, keeping cached DP results and
    # dense transition tables sound even though this manager's state
    # moves with time rather than with allocate/release alone.

    def try_allocate(self, action: Action, units: int) -> Optional[Allocation]:
        if self.mode == "quota":
            self._refill()
            if units > self._tokens:
                return None
            self._tokens -= units
            # occupancy is tracked separately from tokens: the occupancy
            # invariant (task_usage sums to held units) must hold even
            # though availability is the token count, not free slots
            self._in_use += units
            return Allocation(self.rtype, units, detail={"mode": "quota"})
        return super().try_allocate(action, units)

    def release(self, action: Action, allocation: Allocation) -> None:
        if self.mode == "quota":
            # tokens are consumed, not returned — refill restores them —
            # but the units are no longer *occupied* by a running action
            self._in_use -= allocation.units
            assert self._in_use >= 0, f"{self.rtype}: negative occupancy"
            return
        super().release(action, allocation)

    def release_unlaunched(self, action: Action, allocation: Allocation) -> None:
        """Rollback of an acquisition whose action never started (partial
        multi-resource failure, sharded commit conflict): the API call
        was never made, so the tokens are REFUNDED — the plain release
        path would silently burn quota for work that never ran."""
        if self.mode == "quota":
            self._in_use -= allocation.units
            assert self._in_use >= 0, f"{self.rtype}: negative occupancy"
            self._tokens = min(self.spec.quota, self._tokens + allocation.units)
            return
        super().release(action, allocation)

    def time_to_next_refill(self) -> float:
        """Seconds until the next quota refill (inf for concurrency
        mode) — the orchestrator's post-round refill wake reads this."""
        if self.mode != "quota":
            return math.inf
        now = self._clock.now()
        return self._period_start + self.spec.period_s - now

    # ------------------------------------------------------------------
    # wire snapshots (see the ResourceManager base contract)
    # ------------------------------------------------------------------
    def snapshot_state(self) -> dict:
        """Wire twin of ``snapshot()``: spec + token/occupancy state,
        with the quota refill settled at the governing clock's current
        instant so the remote side can pin its clock there
        (:class:`~repro.core.simulator.FrozenClock`) and read the same
        ``available`` the in-process snapshot would."""
        if self.mode == "quota":
            self._refill()
        state = {
            "spec": {
                "name": self.spec.name,
                "mode": self.spec.mode,
                "max_concurrency": self.spec.max_concurrency,
                "quota": self.spec.quota,
                "period_s": self.spec.period_s,
            },
            "now": self._clock.now(),
            "in_use": self._in_use,
            "task_use": dict(self._task_use),
        }
        if self.mode == "quota":
            state["tokens"] = self._tokens
            state["period_start"] = self._period_start
        return state

    @classmethod
    def restore_snapshot(cls, state: dict) -> "BasicResourceManager":
        spec = ApiResourceSpec(
            name=str(state["spec"]["name"]),
            mode=str(state["spec"]["mode"]),
            max_concurrency=int(state["spec"]["max_concurrency"]),
            quota=int(state["spec"]["quota"]),
            period_s=float(state["spec"]["period_s"]),
        )
        m = BasicResourceManager(spec, FrozenClock(float(state.get("now", 0.0))))
        if m.mode == "quota":
            m._tokens = int(state.get("tokens", spec.quota))
            m._period_start = float(state.get("period_start", 0.0))
        m._in_use = int(state.get("in_use", 0))
        m._task_use = {str(k): int(v) for k, v in state.get("task_use", {}).items()}
        return m

    def apply_state(self, state: dict) -> bool:
        """In-place refresh of a restored replica (base contract).  The
        pinned :class:`~repro.core.simulator.FrozenClock` is re-pinned at
        the new snapshot instant — the state dict arrives refill-settled
        at that instant, so the first ``available`` read after a True
        return is a no-op refill and reads exactly the settled tokens."""
        spec = state.get("spec", {})
        if (
            self.spec.name != str(spec.get("name"))
            or self.spec.mode != str(spec.get("mode"))
            or self.spec.max_concurrency != int(spec.get("max_concurrency", -1))
            or self.spec.quota != int(spec.get("quota", -1))
            or self.spec.period_s != float(spec.get("period_s", -1.0))
        ):
            return False
        if not super().apply_state(
            {"rtype": self.rtype, "capacity": self.capacity, **state}
        ):
            return False
        self._clock = FrozenClock(float(state.get("now", 0.0)))
        if self.mode == "quota":
            self._tokens = int(state.get("tokens", self.spec.quota))
            self._period_start = float(state.get("period_start", 0.0))
        return True
