"""Unified resource-manager interface (paper §5).

Heterogeneous resources "expose a standardized interface to the
scheduler, maintaining transparency of heterogeneous resources to the
scheduling algorithm".  The scheduler only ever calls:

* ``can_accommodate(actions)``   — min-requirement + topology admission
  test used to pick the FCFS candidate window (Alg. 1 line 2);
* ``dp_operator(actions)``       — the topology abstraction DPArrange
  runs over (Appendix B);
* ``partition(actions)``         — optional sub-scheduling domains (the
  CPU manager schedules per node, §5.2);
* ``try_allocate / release``     — concrete placement (Breakdown), with
  per-allocation system overhead (cgroup update, service restore, ...);
* ``trajectory_start / trajectory_end`` — lifetime hooks (the CPU
  manager pins trajectory memory while cores are action-scoped).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.action import Action
from repro.core.dparrange import BasicDPOperator, DPOperator


@dataclass
class Allocation:
    """Opaque placement handle returned by a manager."""

    rtype: str
    units: int
    node: Optional[str] = None
    detail: Dict[str, object] = field(default_factory=dict)
    overhead: float = 0.0  # system-overhead seconds charged to the action


class ResourceManager:
    """Base class; also usable directly for simple fungible resources."""

    def __init__(self, rtype: str, capacity: int) -> None:
        self.rtype = rtype
        self.capacity = int(capacity)
        self._in_use = 0

    # ------------------------------------------------------------------
    # capacity / admission
    # ------------------------------------------------------------------
    @property
    def available(self) -> int:
        return self.capacity - self._in_use

    def min_units(self, action: Action) -> int:
        req = action.cost.get(self.rtype)
        return req.min_units if req is not None else 0

    def can_accommodate(self, actions: Sequence[Action]) -> bool:
        """Admission test with every action at least-required units."""
        return sum(self.min_units(a) for a in actions) <= self.available

    # ------------------------------------------------------------------
    # scheduling hooks
    # ------------------------------------------------------------------
    def dp_operator(self, actions: Sequence[Action], reserve: int = 0) -> DPOperator:
        """``reserve`` units are already committed to co-scheduled actions
        in the same round and must be excluded from elastic scaling."""
        return BasicDPOperator(max(0, self.available - reserve))

    def partition(self, actions: Sequence[Action]) -> Dict[str, List[Action]]:
        """Sub-scheduling domains; default: one global domain."""
        return {"*": list(actions)}

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def try_allocate(self, action: Action, units: int) -> Optional[Allocation]:
        if units > self.available:
            return None
        self._in_use += units
        return Allocation(self.rtype, units)

    def release(self, action: Action, allocation: Allocation) -> None:
        self._in_use -= allocation.units
        assert self._in_use >= 0, f"{self.rtype}: negative usage"

    # ------------------------------------------------------------------
    # lifetime hooks
    # ------------------------------------------------------------------
    def trajectory_start(self, trajectory_id: str, metadata: Dict[str, object]) -> bool:
        return True

    def trajectory_end(self, trajectory_id: str) -> None:
        pass

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        return self._in_use / self.capacity if self.capacity else 0.0

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.rtype}: {self._in_use}/{self.capacity})"
