"""Unified resource-manager interface (paper §5).

Heterogeneous resources "expose a standardized interface to the
scheduler, maintaining transparency of heterogeneous resources to the
scheduling algorithm".  The scheduler only ever calls:

* ``can_accommodate(actions)``   — min-requirement + topology admission
  test used to pick the FCFS candidate window (Alg. 1 line 2);
* ``dp_operator(actions)``       — the topology abstraction DPArrange
  runs over (Appendix B);
* ``partition(actions)``         — optional sub-scheduling domains (the
  CPU manager schedules per node, §5.2);
* ``try_allocate / release``     — concrete placement (Breakdown), with
  per-allocation system overhead (cgroup update, service restore, ...);
* ``trajectory_start / trajectory_end`` — lifetime hooks (the CPU
  manager pins trajectory memory while cores are action-scoped).

**Authoritative state vs replicas.**  A manager instance is either the
*authoritative* copy — the one whose ``try_allocate`` decides a launch
— or a *replica* derived from it through the snapshot surface.  Under
the default client-serial commit engine the orchestrator's managers
are authoritative and every snapshot (in-process plan isolation or a
wire ``snapshot_state``) is a plan-phase throwaway.  Under worker-owned
commit (``commit_mode="worker"``) authority moves with the ownership
lease: the shard worker's resident replica commits, and the
orchestrator's manager becomes the *verified replay* copy — it applies
the worker's committed outcomes and must reproduce the worker's
post-commit snapshot fingerprint exactly.  Nothing in the contract
changes per role; what makes the handoff sound is that the snapshot
codecs round-trip the full commit-relevant state (asserted in
``tests/test_wire.py``) and that every mutation happens through the
same methods on whichever copy is authoritative.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence

from repro.core.action import Action
from repro.core.dparrange import BasicDPOperator, DPOperator

#: Sentinel distinguishing "key absent" from "key holds None" in
#: snapshot_delta's per-key comparison.
_MISSING = object()


@dataclass
class Allocation:
    """Opaque placement handle returned by a manager."""

    rtype: str
    units: int
    node: Optional[str] = None
    detail: Dict[str, object] = field(default_factory=dict)
    overhead: float = 0.0  # system-overhead seconds charged to the action


class ResourceManager:
    """Base class; also usable directly for simple fungible resources.

    The scheduler-facing contract (what every manager family must keep
    honest) is the method set documented below: admission
    (:meth:`can_accommodate` / the :meth:`begin_admission` cursor), the
    DP hooks (:meth:`dp_operator` / :meth:`dp_cache_key` /
    :meth:`partition`), placement (:meth:`try_allocate` /
    :meth:`release` and the two failure-path releases), share
    accounting (:meth:`note_allocated` / :meth:`note_released` /
    :meth:`task_usage` / :meth:`check_occupancy`), and the plan-phase
    snapshot surface (:meth:`snapshot`, plus the wire codecs
    :meth:`snapshot_state` / :meth:`restore_snapshot` used by
    :mod:`repro.core.wire` when plans leave the process).  See
    ``docs/architecture.md`` ("Managers") and ``examples/remote_round.py``
    for a worked end-to-end use.
    """

    #: Wire-codec family tag (see :func:`repro.core.wire.encode_snapshot`).
    #: Subclasses of a library manager inherit their family's codec; a
    #: new manager family that adds plan-relevant state must define its
    #: own tag + ``snapshot_state``/``restore_snapshot`` pair and
    #: register it in :mod:`repro.core.wire`.
    wire_impl = "pool"

    #: Does planning over this manager mutate it?  The plan phase only
    #: *reads* the base family (admission cursors are fresh copies from
    #: ``begin_admission``; ``dp_operator`` closes over snapshots), so a
    #: long-lived worker replica can be handed to ``plan_partition``
    #: directly, round after round.  A family whose plan surface writes
    #: into the manager (the CPU manager's trajectory binding via
    #: ``partition()``) sets this True, and the resident-state layer
    #: plans over a throwaway ``snapshot()`` instead — the copy-on-plan
    #: reset.  Keep this honest: a False here with a mutating plan
    #: surface corrupts worker state across rounds (the resident-state
    #: property tests assert snapshot stability after planning).
    plan_mutates = False

    def __init__(self, rtype: str, capacity: int) -> None:
        self.rtype = rtype
        self.capacity = int(capacity)
        self._in_use = 0
        # per-task units currently held (multi-tenant fair share): the
        # orchestrator notes every launch/release here, so accounting is
        # manager-agnostic — subclasses never need to touch it.
        self._task_use: Dict[str, int] = {}

    # ------------------------------------------------------------------
    # capacity / admission
    # ------------------------------------------------------------------
    @property
    def available(self) -> int:
        """Units currently grantable (for quota managers: remaining
        tokens, which is why :meth:`held_units` is a separate notion)."""
        return self.capacity - self._in_use

    def min_units(self, action: Action) -> int:
        """The action's minimum requirement on this resource (0 when
        its cost vector does not touch this rtype)."""
        req = action.cost.get(self.rtype)
        return req.min_units if req is not None else 0

    def can_accommodate(self, actions: Sequence[Action]) -> bool:
        """Admission test with every action at least-required units."""
        state = self.begin_admission()
        return all(self.admit_one(state, a) for a in actions)

    # ------------------------------------------------------------------
    # incremental admission (orchestrator candidate window)
    # ------------------------------------------------------------------
    # ``can_accommodate(prefix)`` re-evaluated for every FCFS prefix is
    # O(n^2) per round.  The orchestrator instead opens one admission
    # cursor per round and feeds actions through it one at a time:
    # ``admit_one`` must behave exactly like extending the prefix, so
    # the incremental window equals the seed's full-rescan window.
    def begin_admission(self) -> object:
        """Opaque mutable cursor over a *copy* of the free state."""
        return [self.available]

    def admit_one(self, state: object, action: Action) -> bool:
        """Extend the admission prefix by one action at min units."""
        need = self.min_units(action)
        if need > state[0]:  # type: ignore[index]
            return False
        state[0] -= need  # type: ignore[index]
        return True

    # ------------------------------------------------------------------
    # scheduling hooks
    # ------------------------------------------------------------------
    def dp_operator(self, actions: Sequence[Action], reserve: int = 0) -> DPOperator:
        """Topology abstraction DPArrange runs over (paper Appendix B).

        ``reserve`` units are already committed to co-scheduled actions
        in the same round and must be excluded from elastic scaling.

        Dense-DP contract (PR 2): the returned operator SHOULD implement
        :meth:`~repro.core.dparrange.DPOperator.transition_table` so the
        scheduler can run DPArrange as vectorized array sweeps — a
        ``state x unit-choice -> next-state`` int table with a ``-1``
        invalid sentinel plus a per-state validity mask.  The operator
        (and therefore the table) must be a PURE function of the
        manager state snapshot taken at this call: any feasibility
        callback it closes over must read a snapshot, never live manager
        state, or cached tables would silently go stale."""
        return BasicDPOperator(max(0, self.available - reserve))

    def dp_cache_key(
        self, actions: Sequence[Action], reserve: int = 0
    ) -> Optional[Hashable]:
        """Hashable key under which DPArrange artifacts over ``actions``
        may be memoized, or None if results are state-dependent in ways
        the key cannot capture.  Contract: equal keys imply
        ``dp_operator`` yields an operator with identical transition
        structure — so the key guards BOTH the per-task-tuple DP-result
        memo and the task-independent dense transition-table cache
        (:class:`~repro.core.dparrange.TransitionTable`).  A manager must
        therefore fold into the key everything its operator's
        transitions/validity read (free units here; the GPU manager adds
        its per-node free-chunk level counts, which is what invalidates
        cached tables when chunks are taken or returned)."""
        return (self.rtype, max(0, self.available - reserve))

    def partition(self, actions: Sequence[Action]) -> Dict[str, List[Action]]:
        """Sub-scheduling domains; default: one global domain."""
        return {"*": list(actions)}

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def try_allocate(self, action: Action, units: int) -> Optional[Allocation]:
        """Concrete placement: grant ``units`` (returning an opaque
        :class:`Allocation` carrying any per-allocation system overhead)
        or return None WITHOUT side effects — a refusal must leave the
        manager exactly as it was, because the orchestrator retries
        refused launches on the ordinary round rail.  Live managers
        only: plan-phase snapshots never place."""
        if units > self.available:
            return None
        self._in_use += units
        return Allocation(self.rtype, units)

    def release(self, action: Action, allocation: Allocation) -> None:
        """Return a completed action's allocation.  Must accept exactly
        the Allocation ``try_allocate`` returned, once."""
        self._in_use -= allocation.units
        assert self._in_use >= 0, f"{self.rtype}: negative usage"

    def release_on_failure(self, action: Action, allocation: Allocation) -> None:
        """Release after a timeout/cancel/failure mid-execution.  Default:
        identical to a normal release; managers with non-returnable
        consumption (quota tokens) or cleanup costs may override."""
        self.release(action, allocation)

    def release_unlaunched(self, action: Action, allocation: Allocation) -> None:
        """Release an allocation whose action NEVER started: the rollback
        of a partial multi-resource acquisition (one manager in the
        vector refused) or of a commit-phase conflict in the sharded
        round engine.  Distinct from :meth:`release_on_failure` because
        the work was never attempted — managers with consumable state
        (quota tokens) must refund it here, where a mid-execution
        failure legitimately consumed it."""
        self.release(action, allocation)

    # ------------------------------------------------------------------
    # multi-tenant share accounting (fed by the orchestrator's launch /
    # release choke points; read by the fairness-aware scheduler)
    # ------------------------------------------------------------------
    def note_allocated(self, task_id: str, units: int) -> None:
        self._task_use[task_id] = self._task_use.get(task_id, 0) + units

    def note_released(self, task_id: str, units: int) -> None:
        left = self._task_use.get(task_id, 0) - units
        if left > 0:
            self._task_use[task_id] = left
        else:
            self._task_use.pop(task_id, None)

    def task_usage(self) -> Dict[str, int]:
        """Units currently held per task (live dict — treat as read-only).

        This measures *occupancy* regardless of the manager's own
        release semantics (quota managers consume tokens on release, but
        the task is still no longer occupying them)."""
        return self._task_use

    def held_units(self) -> int:
        """Total units currently occupied by running actions.  Must equal
        ``sum(task_usage().values())`` at every event boundary — the
        occupancy invariant :meth:`check_occupancy` asserts.  Subclasses
        whose ``available`` is not ``capacity - held`` (quota managers:
        availability is tokens, not free slots) must override."""
        return self._in_use

    def check_occupancy(self) -> None:
        """Assert the multi-tenant occupancy invariant: the per-task
        usage ledger (fed by the orchestrator's launch/release choke
        points) sums exactly to the units the manager itself says are
        held.  A violation means some release path skipped
        ``note_released`` (or double-noted) — the leak that permanently
        inflates quota charging for the leaked task."""
        noted = sum(self._task_use.values())
        held = self.held_units()
        assert noted == held, (
            f"{self.rtype}: occupancy leak — task_usage sums to {noted} "
            f"but {held} unit(s) are held ({dict(self._task_use)})"
        )

    # ------------------------------------------------------------------
    # plan-phase snapshots (sharded scheduling rounds)
    # ------------------------------------------------------------------
    def snapshot(self) -> "ResourceManager":
        """Cheap copy-on-snapshot free-state view for shard planning.

        The returned object supports the full *read/plan* surface the
        scheduling policy touches — ``available``/``capacity``,
        ``begin_admission``/``admit_one``, ``dp_operator``/
        ``dp_cache_key``, ``partition``, ``task_usage``, ``min_units`` —
        without any locking against the live manager: mutations a plan
        makes (admission cursors, the CPU manager's trajectory binding)
        land on the snapshot and are discarded.  Placement
        (``try_allocate``/``release``/``note_*``) must NEVER be called
        on a snapshot; it belongs to the single-threaded commit phase
        against the live manager.  Subclasses with deeper mutable state
        (nodes, chunk allocators, token buckets) extend this."""
        clone = copy.copy(self)
        clone._task_use = dict(self._task_use)
        return clone

    # ------------------------------------------------------------------
    # wire snapshots (out-of-process plan phase, repro.core.wire)
    # ------------------------------------------------------------------
    def snapshot_state(self) -> Dict[str, object]:
        """Plain-dict (JSON-able) encoding of the plan-phase free state.

        Together with :meth:`restore_snapshot` this is the wire twin of
        :meth:`snapshot`: ``restore_snapshot(snapshot_state())`` must
        yield an object whose *plan surface* (``available``, admission
        cursor, ``dp_operator``/``dp_cache_key``, ``partition``,
        ``task_usage``, ``min_units``) behaves identically to an
        in-process snapshot — that equivalence is what makes remote
        plans bit-identical to inline ones.  Subclasses with deeper
        state override both methods (and keep them in sync)."""
        return {
            "rtype": self.rtype,
            "capacity": self.capacity,
            "in_use": self._in_use,
            "task_use": dict(self._task_use),
        }

    @classmethod
    def restore_snapshot(cls, state: Dict[str, object]) -> "ResourceManager":
        """Rebuild a plan-capable snapshot from :meth:`snapshot_state`.

        The result is for PLANNING only — committing against it would
        mutate a copy nobody owns.  Placement always happens on the live
        manager, in the orchestrator's single-threaded commit phase."""
        m = ResourceManager(str(state["rtype"]), int(state["capacity"]))  # type: ignore[arg-type]
        m._in_use = int(state.get("in_use", 0))  # type: ignore[arg-type]
        task_use = dict(state.get("task_use", {}))  # type: ignore[arg-type]
        m._task_use = {str(k): int(v) for k, v in task_use.items()}
        return m

    def apply_state(self, state: Dict[str, object]) -> bool:
        """Refresh this (already-restored) replica in place from a new
        :meth:`snapshot_state` payload, returning True on success.

        This is the cheap path a long-lived worker replica takes between
        rounds: mutable free state is overwritten, immutable topology
        (specs, node objects, allocator shells) is reused, and derived
        caches that depend only on topology stay warm.  Returns False
        when the payload describes a different topology (rtype,
        capacity, node set...) — the caller then falls back to a full
        ``restore_snapshot`` rebuild.  Contract: after a True return,
        ``snapshot_state()`` must equal ``state`` exactly (the resident
        property tests byte-compare them)."""
        if str(state.get("rtype")) != self.rtype or int(
            state.get("capacity", -1)  # type: ignore[arg-type]
        ) != self.capacity:
            return False
        self._in_use = int(state.get("in_use", 0))  # type: ignore[arg-type]
        task_use = dict(state.get("task_use", {}))  # type: ignore[arg-type]
        self._task_use = {str(k): int(v) for k, v in task_use.items()}
        return True

    # ------------------------------------------------------------------
    # structural snapshot deltas (wire twins of snapshot_state)
    # ------------------------------------------------------------------
    @classmethod
    def snapshot_delta(
        cls, prev: Dict[str, object], cur: Dict[str, object]
    ) -> Dict[str, object]:
        """Structural diff between two :meth:`snapshot_state` payloads.

        The base family diffs shallowly, per top-level key: ``set``
        carries keys whose value changed (or appeared), ``del`` lists
        keys that vanished.  Subclasses with deep state (per-node core
        sets, per-allocator chunk maps) override this — and
        :meth:`apply_delta` — so the wire carries what *changed*, not
        the fleet.  Contract: ``apply_delta(prev, snapshot_delta(prev,
        cur)) == cur`` exactly (the receiver fingerprint-verifies it)."""
        delta: Dict[str, object] = {}
        changed = {k: v for k, v in cur.items() if prev.get(k, _MISSING) != v}
        gone = [k for k in prev if k not in cur]
        if changed:
            delta["set"] = changed
        if gone:
            delta["del"] = gone
        return delta

    @classmethod
    def apply_delta(
        cls, base: Dict[str, object], delta: Dict[str, object]
    ) -> Dict[str, object]:
        """Rebuild a full :meth:`snapshot_state` payload from a cached
        base plus a :meth:`snapshot_delta` diff (pure — the base dict is
        not mutated; an empty delta returns an equal copy)."""
        state = dict(base)
        for k, v in delta.get("set", {}).items():  # type: ignore[union-attr]
            state[k] = v
        for k in delta.get("del", []):  # type: ignore[union-attr]
            state.pop(k, None)
        return state

    # ------------------------------------------------------------------
    # lifetime hooks
    # ------------------------------------------------------------------
    def trajectory_start(self, trajectory_id: str, metadata: Dict[str, object]) -> bool:
        """Admit (or veto) a new trajectory.  Called once per trajectory
        before any of its actions are scheduled; managers that pin
        per-trajectory state (the CPU manager's memory binding) hook
        this.  Returning False rejects the trajectory."""
        return True

    def trajectory_end(self, trajectory_id: str) -> None:
        """Release any per-trajectory state pinned by
        :meth:`trajectory_start` (idempotent for unknown ids)."""
        pass

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        """Fraction of capacity currently held by running actions."""
        return self._in_use / self.capacity if self.capacity else 0.0

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.rtype}: {self._in_use}/{self.capacity})"
