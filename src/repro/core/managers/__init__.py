from repro.core.managers.base import Allocation, ResourceManager
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager

__all__ = [
    "Allocation",
    "ResourceManager",
    "BasicResourceManager",
    "CpuManager",
    "GpuManager",
]
