"""CPU manager via AOE — allocate-on-execution (paper §5.2).

**Breakdown**: instead of a pod holding cores for a trajectory's whole
lifetime (k8s baseline), AOE updates the container's cgroup (cpuset /
cpulimit) right before every ``docker.exec`` and reclaims the cores when
the forked process exits.  Trajectory-lifetime state is preserved by
pinning *memory only* (abundant in modern nodes).

**Pool**: cores and memory are jointly managed.  Core selection is
explicit (exclusive cpusets — no interference) and NUMA-aware: an
elastic action's cores are preferentially taken from one NUMA domain.
A trajectory's first action picks a node by a memory load-balancing
policy among nodes that can hold the action's cores *and* the whole
trajectory's memory; all later actions of that trajectory stay on that
node (container residency), so the manager schedules **per node**.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.action import Action
from repro.core.cluster import CpuNodeSpec
from repro.core.dparrange import BasicDPOperator, DPOperator
from repro.core.managers.base import Allocation, ResourceManager

# AOE control-path cost: one docker-API cgroup update + fork (§5.2).
CGROUP_UPDATE_S = 0.002
FORK_EXEC_S = 0.004
DEFAULT_TRAJ_MEM_GB = 4.0


@dataclass
class _NodeState:
    spec: CpuNodeSpec
    free_cores: List[Set[int]] = field(default_factory=list)  # per NUMA domain
    free_mem_gb: float = 0.0
    trajectories: Dict[str, float] = field(default_factory=dict)  # traj -> mem

    def __post_init__(self) -> None:
        per = self.spec.cores_per_numa
        self.free_cores = [
            set(range(d * per, (d + 1) * per)) for d in range(self.spec.numa_nodes)
        ]
        self.free_mem_gb = self.spec.memory_gb

    @property
    def free_core_count(self) -> int:
        return sum(len(s) for s in self.free_cores)

    def take_cores(self, m: int) -> Optional[Tuple[int, ...]]:
        """Exclusive cores, preferring a single NUMA domain (§5.2)."""
        # 1) smallest NUMA domain that fits entirely
        fitting = [d for d in range(len(self.free_cores)) if len(self.free_cores[d]) >= m]
        if fitting:
            d = min(fitting, key=lambda i: len(self.free_cores[i]))
            picked = tuple(sorted(self.free_cores[d]))[:m]
            self.free_cores[d] -= set(picked)
            return picked
        # 2) spill across domains, largest-free first
        if self.free_core_count < m:
            return None
        picked: List[int] = []
        for d in sorted(range(len(self.free_cores)), key=lambda i: -len(self.free_cores[i])):
            grab = tuple(sorted(self.free_cores[d]))[: m - len(picked)]
            picked.extend(grab)
            self.free_cores[d] -= set(grab)
            if len(picked) == m:
                break
        return tuple(picked)

    def return_cores(self, cores: Sequence[int]) -> None:
        per = self.spec.cores_per_numa
        for c in cores:
            self.free_cores[c // per].add(c)

    def clone(self) -> "_NodeState":
        """Free-state copy for plan-phase snapshots (spec is shared —
        it is a frozen dataclass)."""
        c = copy.copy(self)
        c.free_cores = [set(s) for s in self.free_cores]
        c.trajectories = dict(self.trajectories)
        return c


class CpuManager(ResourceManager):
    rtype_mem = "cpu_mem"
    wire_impl = "cpu"
    # ``partition()`` binds trajectories (``_bind`` writes free memory +
    # the binding map), so planning over this family mutates it — a
    # resident worker replica must plan over a throwaway ``snapshot()``.
    plan_mutates = True

    def __init__(self, nodes: Sequence[CpuNodeSpec]) -> None:
        super().__init__("cpu", sum(n.cores for n in nodes))
        self.nodes: Dict[str, _NodeState] = {n.name: _NodeState(n) for n in nodes}
        self._binding: Dict[str, str] = {}  # trajectory -> node name

    # ------------------------------------------------------------------
    @property
    def available(self) -> int:
        return sum(n.free_core_count for n in self.nodes.values())

    def node_of(self, trajectory_id: str) -> Optional[str]:
        return self._binding.get(trajectory_id)

    def held_units(self) -> int:
        return self.capacity - self.available

    def snapshot(self) -> "CpuManager":
        """Plan-phase view: per-node free cores/memory, trajectory
        bindings, and the share ledger are copied, so ``partition()``'s
        trajectory binding during a shard's arrange mutates only the
        snapshot — the live binding happens at commit via
        ``try_allocate``."""
        clone = copy.copy(self)
        clone._task_use = dict(self._task_use)
        clone._binding = dict(self._binding)
        clone.nodes = {name: st.clone() for name, st in self.nodes.items()}
        return clone

    def snapshot_state(self) -> dict:
        """Wire twin of :meth:`snapshot` (see the base contract): node
        specs + per-NUMA free core ids + free memory + trajectory
        bindings, everything ``partition()``'s load-balanced ``_bind``
        and the admission cursor read.  Node ORDER is part of the state
        — ``_bind`` breaks free-memory ties by insertion order."""
        return {
            "nodes": [
                {
                    "spec": {
                        "name": st.spec.name,
                        "cores": st.spec.cores,
                        "numa_nodes": st.spec.numa_nodes,
                        "memory_gb": st.spec.memory_gb,
                    },
                    "free_cores": [sorted(s) for s in st.free_cores],
                    "free_mem_gb": st.free_mem_gb,
                    "trajectories": dict(st.trajectories),
                }
                for st in self.nodes.values()
            ],
            "binding": dict(self._binding),
            "task_use": dict(self._task_use),
        }

    @classmethod
    def restore_snapshot(cls, state: dict) -> "CpuManager":
        specs = [
            CpuNodeSpec(
                name=str(n["spec"]["name"]),
                cores=int(n["spec"]["cores"]),
                numa_nodes=int(n["spec"]["numa_nodes"]),
                memory_gb=float(n["spec"]["memory_gb"]),
            )
            for n in state["nodes"]
        ]
        m = CpuManager(specs)
        for n in state["nodes"]:
            st = m.nodes[str(n["spec"]["name"])]
            st.free_cores = [set(int(c) for c in dom) for dom in n["free_cores"]]
            st.free_mem_gb = float(n["free_mem_gb"])
            st.trajectories = {str(t): float(v) for t, v in n["trajectories"].items()}
        m._binding = {str(t): str(node) for t, node in state.get("binding", {}).items()}
        m._task_use = {str(k): int(v) for k, v in state.get("task_use", {}).items()}
        return m

    def apply_state(self, state: dict) -> bool:
        """In-place refresh (see the base contract): per-node free
        cores/memory/trajectories and the binding map are overwritten;
        node *objects* (and their frozen specs) are reused.  A topology
        change — node count, order, or any spec field — returns False
        for a full rebuild."""
        nodes = state.get("nodes", [])
        if len(nodes) != len(self.nodes):
            return False
        for st, n in zip(self.nodes.values(), nodes):
            spec = n["spec"]
            if (
                st.spec.name != str(spec["name"])
                or st.spec.cores != int(spec["cores"])
                or st.spec.numa_nodes != int(spec["numa_nodes"])
                or st.spec.memory_gb != float(spec["memory_gb"])
            ):
                return False
        if not super().apply_state(
            {"rtype": self.rtype, "capacity": self.capacity, **state}
        ):
            return False
        for st, n in zip(self.nodes.values(), nodes):
            st.free_cores = [set(int(c) for c in dom) for dom in n["free_cores"]]
            st.free_mem_gb = float(n["free_mem_gb"])
            st.trajectories = {str(t): float(v) for t, v in n["trajectories"].items()}
        self._binding = {
            str(t): str(node) for t, node in state.get("binding", {}).items()
        }
        return True

    # ------------------------------------------------------------------
    # structural snapshot deltas (per-node: a round touches few nodes)
    # ------------------------------------------------------------------
    @classmethod
    def snapshot_delta(cls, prev: dict, cur: dict) -> dict:
        """Per-node diff: node ORDER is part of the state (``_bind``'s
        tie-break), so nodes are addressed by position.  Each changed
        node contributes only its changed keys (usually ``free_cores`` /
        ``free_mem_gb`` / ``trajectories``); a topology change (node
        count) falls back to shipping the full node list."""
        pn, cn = prev.get("nodes", []), cur.get("nodes", [])
        delta = super().snapshot_delta(
            {k: v for k, v in prev.items() if k != "nodes"},
            {k: v for k, v in cur.items() if k != "nodes"},
        )
        if len(pn) != len(cn):
            delta.setdefault("set", {})["nodes"] = cn
            return delta
        nodes: dict = {}
        for i, (p, c) in enumerate(zip(pn, cn)):
            if p != c:
                nodes[str(i)] = {k: v for k, v in c.items() if p.get(k) != v}
        if nodes:
            delta["nodes"] = nodes
        return delta

    @classmethod
    def apply_delta(cls, base: dict, delta: dict) -> dict:
        state = super().apply_delta(base, delta)
        patches = delta.get("nodes")
        if patches:
            nodes = [dict(n) for n in state.get("nodes", [])]
            for idx, patch in patches.items():
                i = int(idx)
                if not 0 <= i < len(nodes):
                    from repro.core.wire import WireError

                    raise WireError(f"cpu snapshot delta patches node {i} of {len(nodes)}")
                nodes[i].update(patch)
            state["nodes"] = nodes
        return state

    # ------------------------------------------------------------------
    # trajectory lifetime: bind node + pin memory (Breakdown keeps state)
    # ------------------------------------------------------------------
    def _bind(self, action: Action) -> Optional[str]:
        traj = action.trajectory_id
        if traj in self._binding:
            return self._binding[traj]
        mem = float(action.metadata.get("traj_mem_gb", DEFAULT_TRAJ_MEM_GB))
        need_cores = self.min_units(action)
        # filter: enough cores for the action + memory for the trajectory;
        # select by memory load balancing (most free memory).
        feasible = [
            n
            for n in self.nodes.values()
            if n.free_core_count >= need_cores and n.free_mem_gb >= mem
        ]
        if not feasible:
            return None
        node = max(feasible, key=lambda n: n.free_mem_gb)
        node.free_mem_gb -= mem
        node.trajectories[traj] = mem
        self._binding[traj] = node.spec.name
        return node.spec.name

    def trajectory_end(self, trajectory_id: str) -> None:
        name = self._binding.pop(trajectory_id, None)
        if name is None:
            return
        node = self.nodes[name]
        mem = node.trajectories.pop(trajectory_id, 0.0)
        node.free_mem_gb += mem

    # ------------------------------------------------------------------
    # scheduling hooks: per-node domains (§5.2 last paragraph)
    # ------------------------------------------------------------------
    def partition(self, actions: Sequence[Action]) -> Dict[str, List[Action]]:
        parts: Dict[str, List[Action]] = {}
        for a in actions:
            node = self._bind(a)
            key = node if node is not None else "__unbound__"
            parts.setdefault(key, []).append(a)
        return parts

    def dp_operator(self, actions: Sequence[Action], reserve: int = 0) -> DPOperator:
        # called per partition; all actions share one node after _bind.
        # Cores are fungible within the pool, so the operator is the
        # basic shift topology — its dense transition table is a trivial
        # (free+1)-state shift keyed by the free-core count below.
        nodes = {self._binding.get(a.trajectory_id) for a in actions}
        nodes.discard(None)
        if len(nodes) == 1:
            free = self.nodes[next(iter(nodes))].free_core_count
            return BasicDPOperator(max(0, free - reserve))
        return BasicDPOperator(max(0, self.available - reserve))

    def dp_cache_key(self, actions: Sequence[Action], reserve: int = 0):
        # keys both the DP-result memo and the dense transition-table
        # cache: the node's (or pool's) free-core count is the only state
        # BasicDPOperator reads, so equal keys reproduce equal tables.
        nodes = {self._binding.get(a.trajectory_id) for a in actions}
        nodes.discard(None)
        if len(nodes) == 1:
            name = next(iter(nodes))
            return ("cpu", name, max(0, self.nodes[name].free_core_count - reserve))
        return ("cpu", "*", max(0, self.available - reserve))

    # admission (greedy placement of min requirements respecting bindings);
    # ``can_accommodate`` is the inherited begin/admit loop over this cursor.
    def begin_admission(self) -> object:
        return (
            {n: s.free_core_count for n, s in self.nodes.items()},
            {n: s.free_mem_gb for n, s in self.nodes.items()},
        )

    def admit_one(self, state: object, action: Action) -> bool:
        free, mem = state  # type: ignore[misc]
        need = self.min_units(action)
        bound = self._binding.get(action.trajectory_id)
        if bound is not None:
            if free[bound] < need:
                return False
            free[bound] -= need
            return True
        tmem = float(action.metadata.get("traj_mem_gb", DEFAULT_TRAJ_MEM_GB))
        cands = [n for n in free if free[n] >= need and mem[n] >= tmem]
        if not cands:
            return False
        pick = max(cands, key=lambda n: mem[n])
        free[pick] -= need
        mem[pick] -= tmem
        return True

    # ------------------------------------------------------------------
    # placement (AOE)
    # ------------------------------------------------------------------
    def try_allocate(self, action: Action, units: int) -> Optional[Allocation]:
        name = self._bind(action)
        if name is None:
            return None
        node = self.nodes[name]
        cores = node.take_cores(units)
        if cores is None:
            return None
        numa_domains = {c // node.spec.cores_per_numa for c in cores}
        return Allocation(
            "cpu",
            units,
            node=name,
            detail={"cores": cores, "numa_domains": sorted(numa_domains)},
            overhead=CGROUP_UPDATE_S + FORK_EXEC_S,
        )

    def release(self, action: Action, allocation: Allocation) -> None:
        node = self.nodes[allocation.node]
        node.return_cores(allocation.detail["cores"])  # type: ignore[arg-type]

    def utilization(self) -> float:
        total = self.capacity
        return (total - self.available) / total if total else 0.0

    def memory_utilization(self) -> float:
        total = sum(n.spec.memory_gb for n in self.nodes.values())
        free = sum(n.free_mem_gb for n in self.nodes.values())
        return (total - free) / total if total else 0.0
