"""GPU manager via EOE — evict-on-execution (paper §5.3).

**Breakdown**: every required service is deployed once at init and its
state snapshotted to host memory.  An action requesting service ``s``
with ``m`` devices gets a device *chunk*; if ``s`` (at that DoP) is
already resident on the chunk the action runs immediately (hit),
otherwise the manager restores ``s`` from host memory (miss — restore
latency = state bytes / restore bandwidth), evicting cached services as
needed.  Because service device-state is invariant across invocations,
eviction is *free*: just release device memory, the host copy stays
valid.  Elastic DoP falls out naturally: each DoP configuration of a
service is a distinct service key.

**Pool**: a multi-level *chunk* structure mitigates fragmentation.
A legal chunk is a contiguous device interval ``(start, start + 2^a)``
with ``start % 2^a == 0`` (levels a in {0, 1, 2, 3}).  Allocation of
``m`` devices takes the smallest free chunk of level >= ceil(log2 m),
splitting as needed; when several same-level chunks are free, the one
already caching the requested service is preferred, and otherwise the
**LRU**-cached chunk is the eviction victim (reduces service dithering).

The identical mechanics serve the TPU-slice adaptation (DESIGN.md §3):
a "node" is a v5e tray and chunks are ICI-contiguous slices.
"""

from __future__ import annotations

import copy
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.action import Action
from repro.core.cluster import GpuNodeSpec
from repro.core.dparrange import DPOperator, GpuChunkDPOperator
from repro.core.managers.base import Allocation, ResourceManager

ServiceKey = Tuple[str, int]  # (service name, DoP)

# control-path cost of a cache-hit dispatch (routing + IPC)
DISPATCH_S = 0.001


@dataclass(frozen=True)
class ServiceSpec:
    """A deployable external service (reward model, judge, teacher)."""

    name: str
    state_gb: float  # device-state size at DoP=1 (weights + static buffers)
    dops: Tuple[int, ...] = (1, 2, 4, 8)

    def state_gb_at(self, dop: int) -> float:
        # TP shards weights across the chunk: per-device state shrinks,
        # total restored bytes stay ~constant (plus small per-shard overhead).
        return self.state_gb * (1.0 + 0.03 * (dop - 1))


@dataclass
class _Chunk:
    start: int
    level: int  # size = 2**level

    @property
    def size(self) -> int:
        return 1 << self.level

    def buddy_start(self) -> int:
        return self.start ^ self.size


class ChunkAllocator:
    """Buddy allocator over one node's devices with service-cache tags."""

    def __init__(self, devices: int) -> None:
        if devices & (devices - 1):
            raise ValueError("devices must be a power of two")
        self.devices = devices
        self.max_level = int(math.log2(devices))
        # free chunks: level -> set of starts
        self.free: Dict[int, Set[int]] = {l: set() for l in range(self.max_level + 1)}
        self.free[self.max_level].add(0)
        self.busy: Set[Tuple[int, int]] = set()  # (start, level)
        # cache tags: (start, level) -> (service key, last-used time)
        self.cache: Dict[Tuple[int, int], Tuple[ServiceKey, float]] = {}
        # memoized free_level_counts (invalidated on allocate/release) —
        # admission and DP feasibility hammer it between mutations
        self._level_counts: Optional[List[int]] = None

    # ------------------------------------------------------------------
    @property
    def free_capacity(self) -> int:
        return sum(len(s) << l for l, s in self.free.items())

    def free_level_counts(self) -> List[int]:
        """Free chunk counts per level under maximal buddy merging."""
        if self._level_counts is not None:
            return list(self._level_counts)
        counts = [len(self.free[l]) for l in range(self.max_level + 1)]
        # merging two level-l buddies yields a level-(l+1) chunk; emulate
        # canonical merge on counts using actual adjacency.
        frees = {l: set(s) for l, s in self.free.items()}
        for l in range(self.max_level):
            merged = True
            while merged:
                merged = False
                for start in sorted(frees[l]):
                    buddy = start ^ (1 << l)
                    if buddy in frees[l] and start < buddy:
                        frees[l] -= {start, buddy}
                        frees[l + 1].add(min(start, buddy))
                        merged = True
                        break
        self._level_counts = [len(frees[l]) for l in range(self.max_level + 1)]
        return list(self._level_counts)

    # ------------------------------------------------------------------
    def _evict(self, chunk_key: Tuple[int, int]) -> None:
        """Drop a cache tag (free by §5.3 — host copy is invariant)."""
        self.cache.pop(chunk_key, None)

    def _split(self, start: int, level: int, target: int) -> int:
        """Split a free chunk down to ``target`` level; returns start."""
        self.free[level].discard(start)
        self._evict((start, level))
        while level > target:
            level -= 1
            self.free[level].add(start + (1 << level))
            self.free[level].add(start)
            self.free[level].discard(start)  # keep left half in hand
        return start

    def _try_merge_to(self, target: int) -> Optional[int]:
        """Merge free buddies upward until a level->target chunk exists."""
        for l in range(target):
            for start in sorted(self.free[l]):
                buddy = start ^ (1 << l)
                if buddy in self.free[l]:
                    lo = min(start, buddy)
                    self.free[l] -= {start, buddy}
                    self._evict((start, l))
                    self._evict((buddy, l))
                    self.free[l + 1].add(lo)
        starts = self.free[target]
        return min(starts) if starts else None

    def allocate(
        self, m: int, service: Optional[ServiceKey], now: float
    ) -> Optional[Tuple[int, int, bool]]:
        """Allocate >=m devices; returns (start, level, cache_hit)."""
        if m <= 0 or m > self.devices:
            return None
        self._level_counts = None
        target = max(0, math.ceil(math.log2(m)))
        # 1) exact-level free chunk, preferring a cache hit, then untagged,
        #    then the LRU-tagged chunk (eviction victim).
        pool = self.free[target]
        if pool:
            hit = [
                s for s in pool if self.cache.get((s, target), (None, 0.0))[0] == service
            ]
            if hit and service is not None:
                start = min(hit)
                self.free[target].discard(start)
                self.busy.add((start, target))
                return start, target, True
            untagged = [s for s in pool if (s, target) not in self.cache]
            if untagged:
                start = min(untagged)
            else:
                start = min(pool, key=lambda s: self.cache[(s, target)][1])  # LRU
                self._evict((start, target))
            self.free[target].discard(start)
            self.busy.add((start, target))
            return start, target, False
        # 2) split a larger free chunk (smallest sufficient level first,
        #    untagged preferred to avoid eviction).
        for l in range(target + 1, self.max_level + 1):
            if self.free[l]:
                untagged = [s for s in self.free[l] if (s, l) not in self.cache]
                cand = (
                    min(untagged)
                    if untagged
                    else min(self.free[l], key=lambda s: self.cache[(s, l)][1])
                )
                start = self._split(cand, l, target)
                self.busy.add((start, target))
                return start, target, False
        # 3) merge smaller free buddies upward
        start = self._try_merge_to(target)
        if start is not None:
            self.free[target].discard(start)
            self.busy.add((start, target))
            return start, target, False
        return None

    def release(self, start: int, level: int, service: Optional[ServiceKey], now: float) -> None:
        key = (start, level)
        assert key in self.busy, f"releasing non-busy chunk {key}"
        self._level_counts = None
        self.busy.discard(key)
        self.free[level].add(start)
        if service is not None:
            self.cache[key] = (service, now)  # stays cached until evicted

    def touch(self, start: int, level: int, now: float) -> None:
        key = (start, level)
        if key in self.cache:
            svc, _ = self.cache[key]
            self.cache[key] = (svc, now)

    def clone(self) -> "ChunkAllocator":
        """Free-state copy for plan-phase snapshots."""
        c = copy.copy(self)
        c.free = {lvl: set(s) for lvl, s in self.free.items()}
        c.busy = set(self.busy)
        c.cache = dict(self.cache)
        return c

    # -- invariants (property-tested) -----------------------------------
    def check_invariants(self) -> None:
        covered: Set[int] = set()
        for l, starts in self.free.items():
            for s in starts:
                assert s % (1 << l) == 0, f"illegal chunk ({s},{l})"
                rng = set(range(s, s + (1 << l)))
                assert not (covered & rng), "overlapping free chunks"
                covered |= rng
        for s, l in self.busy:
            assert s % (1 << l) == 0, f"illegal busy chunk ({s},{l})"
            rng = set(range(s, s + (1 << l)))
            assert not (covered & rng), "busy overlaps"
            covered |= rng
        assert covered == set(range(self.devices)), "devices lost or duplicated"


class GpuManager(ResourceManager):
    wire_impl = "gpu"

    def __init__(self, nodes: Sequence[GpuNodeSpec], services: Sequence[ServiceSpec]) -> None:
        super().__init__("gpu", sum(n.devices for n in nodes))
        self.node_specs = {n.name: n for n in nodes}
        self.allocators = {n.name: ChunkAllocator(n.devices) for n in nodes}
        self.services = {s.name: s for s in services}
        # EOE init: deploy each service once, snapshot to host memory.
        host_need = sum(s.state_gb_at(max(s.dops)) for s in services)
        host_have = sum(n.host_memory_gb for n in nodes)
        if host_need > host_have:
            raise ValueError(
                f"host memory insufficient for snapshots: {host_need} > {host_have}"
            )
        self.stats = {"hits": 0, "misses": 0, "restore_s": 0.0}
        self._now = 0.0  # advanced by the Tangram loop for LRU ordering

    # ------------------------------------------------------------------
    def set_time(self, now: float) -> None:
        self._now = now

    @property
    def available(self) -> int:
        return sum(a.free_capacity for a in self.allocators.values())

    def held_units(self) -> int:
        return self.capacity - self.available

    def check_occupancy(self) -> None:
        """Chunk-granular variant of the occupancy invariant: a busy
        chunk rounds an allocation up to a power of two, so held devices
        may exceed the noted units — but never the reverse (noted units
        outliving their chunks is exactly the note_released leak), and
        the ledger must empty when the last chunk frees."""
        noted = sum(self._task_use.values())
        held = self.held_units()
        assert noted <= held, (
            f"{self.rtype}: occupancy leak — task_usage sums to {noted} "
            f"but only {held} device(s) are held ({dict(self._task_use)})"
        )
        assert (noted == 0) == (held == 0), (
            f"{self.rtype}: occupancy leak — noted {noted} vs held {held}"
        )

    def snapshot(self) -> "GpuManager":
        """Plan-phase view: chunk allocators (free/busy/cache tags) and
        the share ledger are copied; specs/services are shared
        (immutable).  ``stats`` stays shared — planning never calls
        ``try_allocate``, the only mutator of it."""
        clone = copy.copy(self)
        clone._task_use = dict(self._task_use)
        clone.allocators = {n: a.clone() for n, a in self.allocators.items()}
        return clone

    def snapshot_state(self) -> dict:
        """Wire twin of :meth:`snapshot` (see the base contract): node +
        service specs and each allocator's free/busy chunk sets and
        service-cache tags (the DP feasibility callback and admission
        read the free sets; cache tags matter only for commit-side
        placement but round-trip so the codec is lossless)."""
        return {
            "nodes": [
                {
                    "name": n.name,
                    "devices": n.devices,
                    "device_memory_gb": n.device_memory_gb,
                    "host_memory_gb": n.host_memory_gb,
                    "restore_bw_gbps": n.restore_bw_gbps,
                }
                for n in self.node_specs.values()
            ],
            "services": [
                {"name": s.name, "state_gb": s.state_gb, "dops": list(s.dops)}
                for s in self.services.values()
            ],
            "allocators": {
                name: {
                    "free": {str(lvl): sorted(starts) for lvl, starts in a.free.items()},
                    "busy": [[s, l] for s, l in sorted(a.busy)],
                    "cache": [
                        [s, l, svc[0], svc[1], t]
                        for (s, l), (svc, t) in sorted(a.cache.items())
                    ],
                }
                for name, a in self.allocators.items()
            },
            "now": self._now,
            "task_use": dict(self._task_use),
        }

    @classmethod
    def restore_snapshot(cls, state: dict) -> "GpuManager":
        nodes = [
            GpuNodeSpec(
                name=str(n["name"]),
                devices=int(n["devices"]),
                device_memory_gb=float(n["device_memory_gb"]),
                host_memory_gb=float(n["host_memory_gb"]),
                restore_bw_gbps=float(n["restore_bw_gbps"]),
            )
            for n in state["nodes"]
        ]
        services = [
            ServiceSpec(
                name=str(s["name"]),
                state_gb=float(s["state_gb"]),
                dops=tuple(int(d) for d in s["dops"]),
            )
            for s in state["services"]
        ]
        m = GpuManager(nodes, services)
        for name, st in state["allocators"].items():
            alloc = m.allocators[str(name)]
            alloc.free = {
                lvl: set(int(s) for s in st["free"].get(str(lvl), []))
                for lvl in range(alloc.max_level + 1)
            }
            alloc.busy = {(int(s), int(l)) for s, l in st["busy"]}
            alloc.cache = {
                (int(s), int(l)): ((str(svc), int(dop)), float(t))
                for s, l, svc, dop, t in st["cache"]
            }
            alloc._level_counts = None
        m._now = float(state.get("now", 0.0))
        m._task_use = {str(k): int(v) for k, v in state.get("task_use", {}).items()}
        return m

    def apply_state(self, state: dict) -> bool:
        """In-place refresh of a restored replica (base contract): each
        allocator's free/busy chunk sets and cache tags are overwritten
        and its memoized free-level counts invalidated; allocator shells,
        node specs, and service specs are reused.  Node or service
        topology changes return False for a full rebuild."""
        nodes = state.get("nodes", [])
        if [
            (n["name"], n["devices"], n["device_memory_gb"], n["host_memory_gb"],
             n["restore_bw_gbps"])
            for n in nodes
        ] != [
            (s.name, s.devices, s.device_memory_gb, s.host_memory_gb,
             s.restore_bw_gbps)
            for s in self.node_specs.values()
        ]:
            return False
        services = state.get("services", [])
        if [
            (s["name"], s["state_gb"], tuple(s["dops"])) for s in services
        ] != [(s.name, s.state_gb, s.dops) for s in self.services.values()]:
            return False
        if set(state.get("allocators", {})) != set(self.allocators):
            return False
        if not super().apply_state(
            {"rtype": self.rtype, "capacity": self.capacity, **state}
        ):
            return False
        for name, st in state["allocators"].items():
            alloc = self.allocators[str(name)]
            alloc.free = {
                lvl: set(int(s) for s in st["free"].get(str(lvl), []))
                for lvl in range(alloc.max_level + 1)
            }
            alloc.busy = {(int(s), int(l)) for s, l in st["busy"]}
            alloc.cache = {
                (int(s), int(l)): ((str(svc), int(dop)), float(t))
                for s, l, svc, dop, t in st["cache"]
            }
            alloc._level_counts = None
        self._now = float(state.get("now", 0.0))
        return True

    # ------------------------------------------------------------------
    # structural snapshot deltas (chunk-level: the free map dominates)
    # ------------------------------------------------------------------
    @classmethod
    def snapshot_delta(cls, prev: dict, cur: dict) -> dict:
        """Chunk-level diff.  The allocator free/busy/cache maps are the
        bytes-dominant part of a GPU snapshot and a round touches only
        the chunks it (de)allocated, so each allocator contributes
        per-level ``add``/``rm`` start lists (free), row add/removals
        (busy), and keyed upserts (cache).  Node/service specs are
        immutable and never re-travel; an allocator-set change (topology)
        falls back to the full map."""
        delta = super().snapshot_delta(
            {k: v for k, v in prev.items() if k != "allocators"},
            {k: v for k, v in cur.items() if k != "allocators"},
        )
        pa, ca = prev.get("allocators", {}), cur.get("allocators", {})
        if set(pa) != set(ca):
            delta.setdefault("set", {})["allocators"] = ca
            return delta
        allocs: dict = {}
        for name, c in ca.items():
            p = pa[name]
            if p == c:
                continue
            ad: dict = {}
            free: dict = {}
            for lvl in set(p.get("free", {})) | set(c.get("free", {})):
                ps = set(p.get("free", {}).get(lvl, ()))
                cs = set(c.get("free", {}).get(lvl, ()))
                if ps != cs:
                    lv: dict = {}
                    if cs - ps:
                        lv["add"] = sorted(cs - ps)
                    if ps - cs:
                        lv["rm"] = sorted(ps - cs)
                    free[lvl] = lv
            if free:
                ad["free"] = free
            pb = {(s, l) for s, l in p.get("busy", ())}
            cb = {(s, l) for s, l in c.get("busy", ())}
            if pb != cb:
                bd: dict = {}
                if cb - pb:
                    bd["add"] = [[s, l] for s, l in sorted(cb - pb)]
                if pb - cb:
                    bd["rm"] = [[s, l] for s, l in sorted(pb - cb)]
                ad["busy"] = bd
            pc = {(r[0], r[1]): r for r in p.get("cache", ())}
            cc = {(r[0], r[1]): r for r in c.get("cache", ())}
            add = [r for k, r in sorted(cc.items()) if pc.get(k) != r]
            rm = [[s, l] for s, l in sorted(pc) if (s, l) not in cc]
            if add or rm:
                cd: dict = {}
                if add:
                    cd["add"] = add
                if rm:
                    cd["rm"] = rm
                ad["cache"] = cd
            if ad:
                allocs[name] = ad
        if allocs:
            delta["alloc"] = allocs
        return delta

    @classmethod
    def apply_delta(cls, base: dict, delta: dict) -> dict:
        state = super().apply_delta(base, delta)
        patches = delta.get("alloc")
        if not patches:
            return state
        allocators = {n: dict(a) for n, a in state.get("allocators", {}).items()}
        for name, ad in patches.items():
            if name not in allocators:
                from repro.core.wire import WireError

                raise WireError(f"gpu snapshot delta patches unknown allocator {name!r}")
            a = allocators[name]
            if "free" in ad:
                free = {lvl: list(starts) for lvl, starts in a.get("free", {}).items()}
                for lvl, lv in ad["free"].items():
                    starts = set(free.get(lvl, ()))
                    starts |= set(lv.get("add", ()))
                    starts -= set(lv.get("rm", ()))
                    free[lvl] = sorted(starts)
                a["free"] = free
            if "busy" in ad:
                busy = {(s, l) for s, l in a.get("busy", ())}
                busy |= {(s, l) for s, l in ad["busy"].get("add", ())}
                busy -= {(s, l) for s, l in ad["busy"].get("rm", ())}
                a["busy"] = [[s, l] for s, l in sorted(busy)]
            if "cache" in ad:
                cache = {(r[0], r[1]): r for r in a.get("cache", ())}
                for r in ad["cache"].get("add", ()):
                    cache[(r[0], r[1])] = r
                for s, l in ad["cache"].get("rm", ()):
                    cache.pop((s, l), None)
                a["cache"] = [r for _, r in sorted(cache.items())]
            allocators[name] = a
        state["allocators"] = allocators
        return state

    # ------------------------------------------------------------------
    def begin_admission(self) -> object:
        return [0, 0, 0, 0]  # accumulated chunk-consumption multiset

    def admit_one(self, state: object, action: Action) -> bool:
        need = self.min_units(action)
        if need == 0:
            return True
        dec = GpuChunkDPOperator.greedy_decompose(
            1 << max(0, math.ceil(math.log2(need)))
        )
        if dec is None:
            return False
        trial = [x + y for x, y in zip(state, dec)]  # type: ignore[arg-type]
        if not self.feasible_multiset(tuple(trial)):
            return False
        state[:] = trial  # type: ignore[index]
        return True

    def free_level_snapshot(self) -> Tuple[Tuple[int, ...], ...]:
        """Canonical per-node free-chunk level counts (maximal merging)."""
        return tuple(
            tuple(a.free_level_counts()) for a in self.allocators.values()
        )

    @staticmethod
    def _fit_multiset(
        snapshot: Tuple[Tuple[int, ...], ...], counts: Tuple[int, int, int, int]
    ) -> bool:
        """Pure first-fit of a consumption multiset against a free-chunk
        snapshot — the feasibility test behind the DP operator.  Pure so
        the operator (and any dense transition table enumerated from it)
        is a function of the snapshot alone, cacheable under
        ``dp_cache_key``."""
        node_levels = [list(levels) for levels in snapshot]
        for size_idx in (3, 2, 1, 0):  # large chunks first
            size_level = size_idx
            for _ in range(counts[size_idx]):
                placed = False
                # smallest-sufficient-level fit across nodes
                for lvl in range(size_level, 4):
                    for c in node_levels:
                        if len(c) > lvl and c[lvl] > 0:
                            c[lvl] -= 1
                            for l in range(size_level, lvl):  # split remainder
                                c[l] += 1
                            placed = True
                            break
                    if placed:
                        break
                if not placed:
                    return False
        return True

    def feasible_multiset(self, counts: Tuple[int, int, int, int]) -> bool:
        """Can the pooled free chunks satisfy this consumption multiset?"""
        return self._fit_multiset(self.free_level_snapshot(), counts)

    def dp_operator(self, actions: Sequence[Action], reserve: int = 0) -> DPOperator:
        free = max(0, self.available - reserve)
        max_counts = (free, free // 2, free // 4, free // 8)
        # close the feasibility callback over a SNAPSHOT (not live
        # allocator state): the dense transition table enumerated from
        # this operator is cached on dp_cache_key, and the snapshot is
        # exactly what that key captures.
        snapshot = self.free_level_snapshot()
        return GpuChunkDPOperator(
            max_counts,
            feasible=lambda counts: self._fit_multiset(snapshot, counts),
            total_devices=free,
        )

    def dp_cache_key(self, actions: Sequence[Action], reserve: int = 0):
        # the DP's feasibility callback reads only the canonical per-node
        # free-chunk level counts, so they (plus the unit budget) key it;
        # chunk allocate/release rotates the key, which is what expires
        # cached dense transition tables (regression-tested).
        return (
            "gpu",
            max(0, self.available - reserve),
            self.free_level_snapshot(),
        )

    # ------------------------------------------------------------------
    def try_allocate(self, action: Action, units: int) -> Optional[Allocation]:
        if action.service is not None and action.service not in self.services:
            raise KeyError(f"service {action.service!r} never deployed (EOE inits all)")
        key: Optional[ServiceKey] = (
            (action.service, units) if action.service is not None else None
        )
        # prefer a node whose allocator holds a cache hit at the right level
        target = max(0, math.ceil(math.log2(max(1, units))))
        ordered = sorted(
            self.allocators.items(),
            key=lambda kv: 0 if self._has_hit(kv[1], target, key) else 1,
        )
        for name, alloc in ordered:
            got = alloc.allocate(units, key, self._now)
            if got is None:
                continue
            start, level, hit = got
            overhead = DISPATCH_S
            if key is not None and not hit:
                spec = self.services[action.service]
                node = self.node_specs[name]
                restore = spec.state_gb_at(units) / node.restore_bw_gbps
                overhead += restore
                self.stats["misses"] += 1
                self.stats["restore_s"] += restore
            elif key is not None:
                self.stats["hits"] += 1
            return Allocation(
                "gpu",
                units,
                node=name,
                detail={"start": start, "level": level, "service": key, "hit": hit},
                overhead=overhead,
            )
        return None

    @staticmethod
    def _has_hit(alloc: ChunkAllocator, level: int, key: Optional[ServiceKey]) -> bool:
        if key is None or level > alloc.max_level:
            return False
        return any(
            alloc.cache.get((s, level), (None, 0.0))[0] == key for s in alloc.free[level]
        )

    def release(self, action: Action, allocation: Allocation) -> None:
        alloc = self.allocators[allocation.node]
        alloc.release(
            allocation.detail["start"],  # type: ignore[arg-type]
            allocation.detail["level"],  # type: ignore[arg-type]
            allocation.detail["service"],  # type: ignore[arg-type]
            self._now,
        )

    def utilization(self) -> float:
        total = self.capacity
        return (total - self.available) / total if total else 0.0

    def hit_rate(self) -> float:
        h, m = self.stats["hits"], self.stats["misses"]
        return h / (h + m) if h + m else 0.0
