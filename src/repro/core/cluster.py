"""Cluster topology specifications for the external-resource pools.

The paper's testbed (§6.1): a CPU cluster of 15 nodes (256 AMD cores,
2.4 TB RAM each) and a GPU cluster of 5 nodes (8 high-end GPUs, 3 TB host
RAM each), plus rate-limited API services.  These specs parameterize the
resource managers; nothing here touches JAX.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class CpuNodeSpec:
    name: str
    cores: int = 256
    numa_nodes: int = 2  # cores split evenly across NUMA domains
    memory_gb: float = 2400.0

    @property
    def cores_per_numa(self) -> int:
        return self.cores // self.numa_nodes


@dataclass(frozen=True)
class GpuNodeSpec:
    """One accelerator node.

    ``devices`` is 8 for the paper's NVLink nodes; for the TPU-slice
    adaptation (DESIGN.md §3) a "node" is a v5e tray and chunks are
    ICI-contiguous 1/2/4/8-chip slices — same radix, different constant
    names.  ``host_memory_gb`` bounds how many service snapshots EOE can
    keep host-resident (3 TB in the paper's testbed).
    """

    name: str
    devices: int = 8
    device_memory_gb: float = 80.0
    host_memory_gb: float = 3072.0
    restore_bw_gbps: float = 64.0  # host->device snapshot restore bandwidth


@dataclass(frozen=True)
class ApiResourceSpec:
    """A rate-limited external API (Basic manager, §5.1)."""

    name: str
    mode: str = "concurrency"  # "concurrency" | "quota"
    max_concurrency: int = 64
    quota: int = 1000  # tokens per period (quota mode)
    period_s: float = 60.0


@dataclass(frozen=True)
class ClusterSpec:
    cpu_nodes: Tuple[CpuNodeSpec, ...] = ()
    gpu_nodes: Tuple[GpuNodeSpec, ...] = ()
    apis: Tuple[ApiResourceSpec, ...] = ()

    @property
    def total_cores(self) -> int:
        return sum(n.cores for n in self.cpu_nodes)

    @property
    def total_devices(self) -> int:
        return sum(n.devices for n in self.gpu_nodes)


def paper_testbed(
    cpu_nodes: int = 15,
    cores_per_node: int = 256,
    gpu_nodes: int = 5,
    devices_per_node: int = 8,
) -> ClusterSpec:
    """The paper's §6.1 testbed (sizes overridable for scaled benchmarks)."""
    return ClusterSpec(
        cpu_nodes=tuple(
            CpuNodeSpec(name=f"cpu{i}", cores=cores_per_node) for i in range(cpu_nodes)
        ),
        gpu_nodes=tuple(
            GpuNodeSpec(name=f"gpu{i}", devices=devices_per_node)
            for i in range(gpu_nodes)
        ),
        apis=(
            ApiResourceSpec("google_search", mode="quota", quota=600, period_s=60.0),
            ApiResourceSpec("web_fetch", mode="concurrency", max_concurrency=128),
            ApiResourceSpec("pdf_parse", mode="concurrency", max_concurrency=32),
        ),
    )


def tpu_reward_pool(trays: int = 5, chips_per_tray: int = 8) -> ClusterSpec:
    """TPU-slice adaptation of the reward pool (DESIGN.md §3)."""
    return ClusterSpec(
        gpu_nodes=tuple(
            GpuNodeSpec(
                name=f"tray{i}",
                devices=chips_per_tray,
                device_memory_gb=16.0,  # v5e HBM
                restore_bw_gbps=100.0,
            )
            for i in range(trays)
        )
    )
