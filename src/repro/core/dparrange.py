"""Topology-agnostic DPArrange (paper Appendix B, Algorithms 3 & 4).

Given a set of *scalable* candidate actions, their supported unit sets
``S_i`` and per-allocation durations ``T_i(k)``, DPArrange finds the
discrete allocation minimizing the total execution time subject to the
resource's **topology**, abstracted behind a DP *operator* providing
``Start / End / Prev / IsValid`` primitives:

* :class:`BasicDPOperator` — fungible units (CPU cores within a node,
  concurrency slots): state = units consumed so far.
* :class:`GpuChunkDPOperator` — power-of-two chunk topology (paper
  Algorithm 4): state = mixed-radix-encoded counts of consumed chunks of
  sizes {1, 2, 4, 8}; ``Prev`` greedily decomposes an allocation into
  chunks from largest to smallest.  Where the paper bounds states by
  fixed maximum chunk counts ``(N1, N2, N4, N8)``, we additionally accept
  an exact feasibility callback from the chunk allocator (buddy-splitting
  aware) — the operator interface the paper prescribes, with a sharper
  validity test.  The same operator serves the TPU-slice adaptation
  (ICI-contiguous 1/2/4/8-chip slices), demonstrating topology-agnosticism.

Deviation note: Algorithm 3 line 25 returns ``dp[m][n]`` (exactly-n
consumption).  With discrete unit sets an exact-n composition may not
exist (e.g. sets {1,4}x2, n=7), so we return the best *feasible* final
state ``argmin_j dp[m][j]`` — identical when exact-n is feasible, and
well-defined otherwise.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple

INF = math.inf


@dataclass(frozen=True)
class DPTask:
    """One scalable candidate: supported unit set + duration model."""

    name: str
    units: Tuple[int, ...]  # S_i, sorted ascending
    durations: Tuple[float, ...]  # T_i(k) for each k in units


class DPOperator:
    """Paper's "Basic DP Operator" interface (Algorithm 3 requirements)."""

    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        raise NotImplementedError

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        """Largest state index worth visiting."""
        raise NotImplementedError

    def prev(self, j: int, k: int) -> Optional[int]:
        """Predecessor state before allocating ``k`` units; None if invalid."""
        raise NotImplementedError

    def is_valid(self, j: int) -> bool:
        raise NotImplementedError


class BasicDPOperator(DPOperator):
    """Fungible-unit topology: state ``j`` = units consumed so far."""

    def __init__(self, total_units: int) -> None:
        self.total_units = int(total_units)

    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        return sum(min(s) for s in unit_sets)

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        return min(self.total_units, sum(max(s) for s in unit_sets))

    def prev(self, j: int, k: int) -> Optional[int]:
        p = j - k
        return p if p >= 0 else None

    def is_valid(self, j: int) -> bool:
        return 0 <= j <= self.total_units


class GpuChunkDPOperator(DPOperator):
    """Paper Algorithm 4: chunk-count states over sizes {1, 2, 4, 8}.

    State ``(a, b, c, d)`` counts *consumed* chunks of sizes 1/2/4/8,
    linearized with mixed-radix encoding (collision-free, finite).
    ``feasible`` — supplied by the chunk allocator — answers whether the
    current free-chunk configuration can yield that consumption multiset
    (buddy splitting allowed).
    """

    SIZES = (1, 2, 4, 8)

    def __init__(
        self,
        max_counts: Tuple[int, int, int, int],
        feasible: Optional[Callable[[Tuple[int, int, int, int]], bool]] = None,
        total_devices: Optional[int] = None,
    ) -> None:
        self.max_counts = tuple(int(n) for n in max_counts)
        self._radix = tuple(n + 1 for n in self.max_counts)
        self.total_devices = total_devices
        self._feasible = feasible
        # memoize feasibility — the DP revisits states heavily
        if feasible is not None:
            self._feasible = lru_cache(maxsize=None)(feasible)

    # -- mixed-radix encoding (Algorithm 4 Encode/Decode) -----------------
    def encode(self, counts: Tuple[int, int, int, int]) -> int:
        a, b, c, d = counts
        r1, r2, r4, _ = self._radix
        return a + r1 * (b + r2 * (c + r4 * d))

    def decode(self, j: int) -> Tuple[int, int, int, int]:
        r1, r2, r4, _ = self._radix
        a = j % r1
        j //= r1
        b = j % r2
        j //= r2
        c = j % r4
        j //= r4
        return (a, b, c, j)

    @staticmethod
    def greedy_decompose(k: int) -> Optional[Tuple[int, int, int, int]]:
        """Decompose ``k`` devices into chunk counts, largest first."""
        if k <= 0:
            return None
        counts = [0, 0, 0, 0]
        need = k
        for idx in (3, 2, 1, 0):
            size = GpuChunkDPOperator.SIZES[idx]
            counts[idx] = need // size
            need -= counts[idx] * size
        if need:
            return None
        return tuple(counts)  # type: ignore[return-value]

    # -- operator primitives ----------------------------------------------
    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        counts = [0, 0, 0, 0]
        for s in unit_sets:
            dec = self.greedy_decompose(min(s))
            if dec is None:
                return 0
            counts = [x + y for x, y in zip(counts, dec)]
        counts = [min(x, n) for x, n in zip(counts, self.max_counts)]
        return self.encode(tuple(counts))  # type: ignore[arg-type]

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        r1, r2, r4, r8 = self._radix
        return r1 * r2 * r4 * r8 - 1

    def prev(self, j: int, k: int) -> Optional[int]:
        a, b, c, d = self.decode(j)
        need = k
        use_d = min(d, need // 8)
        need -= 8 * use_d
        use_c = min(c, need // 4)
        need -= 4 * use_c
        use_b = min(b, need // 2)
        need -= 2 * use_b
        use_a = min(a, need)
        need -= use_a
        if need > 0:
            return None  # not enough chunks in-state to satisfy k
        return self.encode((a - use_a, b - use_b, c - use_c, d - use_d))

    def is_valid(self, j: int) -> bool:
        counts = self.decode(j)
        if any(x < 0 or x > n for x, n in zip(counts, self.max_counts)):
            return False
        if self.total_devices is not None:
            used = sum(c * s for c, s in zip(counts, self.SIZES))
            if used > self.total_devices:
                return False
        if self._feasible is not None and not self._feasible(counts):
            return False
        return True


@dataclass
class DPResult:
    total_duration: float
    allocation: Dict[str, int]  # task name -> units
    durations: Dict[str, float]  # task name -> T_i(k_i)


def dp_arrange(tasks: Sequence[DPTask], operator: DPOperator) -> Optional[DPResult]:
    """Algorithm 3.  Returns None when even minimal allocation is infeasible."""
    m = len(tasks)
    if m == 0:
        return DPResult(0.0, {}, {})
    unit_sets = [t.units for t in tasks]
    n = operator.end(unit_sets)
    if n < 0:
        return None

    # dp maps state -> best total duration for the first i tasks; we keep
    # two rolling rows plus a choice table for backtracking.
    prev_row: Dict[int, float] = {}
    start0 = 0
    if operator.is_valid(start0):
        prev_row[start0] = 0.0
    if not prev_row:
        return None
    choice: List[Dict[int, Tuple[int, int]]] = []  # [i] state -> (k, prev_state)

    for i, task in enumerate(tasks):
        cur_row: Dict[int, float] = {}
        cur_choice: Dict[int, Tuple[int, int]] = {}
        for jp, base in prev_row.items():
            for k, dur in zip(task.units, task.durations):
                # forward transition: state jp --(allocate k to task i)--> j
                j = _forward(operator, jp, k)
                if j is None or j > n or not operator.is_valid(j):
                    continue
                total = base + dur
                if total < cur_row.get(j, INF):
                    cur_row[j] = total
                    cur_choice[j] = (k, jp)
        if not cur_row:
            return None
        prev_row = cur_row
        choice.append(cur_choice)

    best_state = min(prev_row, key=lambda s: prev_row[s])
    best = prev_row[best_state]

    # backtrack
    alloc: Dict[str, int] = {}
    durs: Dict[str, float] = {}
    state = best_state
    for i in range(m - 1, -1, -1):
        k, pstate = choice[i][state]
        alloc[tasks[i].name] = k
        kidx = tasks[i].units.index(k)
        durs[tasks[i].name] = tasks[i].durations[kidx]
        state = pstate
    return DPResult(best, alloc, durs)


def dp_arrange_prefixes(
    tasks: Sequence[DPTask], operator: DPOperator
) -> List[Optional[DPResult]]:
    """DPResult for every prefix ``tasks[:i]`` (i = 0..m) in ONE DP pass.

    Greedy eviction (Alg. 1) always evicts the LAST candidate, so the
    objective of every kept-set it evaluates is a prefix of the same DP —
    one pass over the rows serves the whole eviction loop (this is what
    keeps the scheduler inside the paper's O(k n^2 m^2) bound).
    """
    m = len(tasks)
    results: List[Optional[DPResult]] = [DPResult(0.0, {}, {})]
    rows: List[Dict[int, float]] = [{0: 0.0} if operator.is_valid(0) else {}]
    choices: List[Dict[int, Tuple[int, int]]] = []
    unit_sets = [t.units for t in tasks]
    n = operator.end(unit_sets)
    for i, task in enumerate(tasks):
        prev_row = rows[-1]
        cur_row: Dict[int, float] = {}
        cur_choice: Dict[int, Tuple[int, int]] = {}
        for jp, base in prev_row.items():
            for k, dur in zip(task.units, task.durations):
                j = _forward(operator, jp, k)
                if j is None or j > n or not operator.is_valid(j):
                    continue
                total = base + dur
                if total < cur_row.get(j, INF):
                    cur_row[j] = total
                    cur_choice[j] = (k, jp)
        rows.append(cur_row)
        choices.append(cur_choice)
        if not cur_row:
            results.append(None)
            continue
        best_state = min(cur_row, key=lambda s: cur_row[s])
        alloc: Dict[str, int] = {}
        durs: Dict[str, float] = {}
        state = best_state
        feasible = True
        for t in range(i, -1, -1):
            if state not in choices[t]:
                feasible = False
                break
            k, pstate = choices[t][state]
            alloc[tasks[t].name] = k
            durs[tasks[t].name] = tasks[t].durations[tasks[t].units.index(k)]
            state = pstate
        results.append(
            DPResult(cur_row[best_state], alloc, durs) if feasible else None
        )
    return results


def _forward(operator: DPOperator, jp: int, k: int) -> Optional[int]:
    """Invert ``Prev``: the state reached from ``jp`` by allocating ``k``.

    For the basic operator this is ``jp + k``; for the chunk operator we
    add the greedy decomposition (the exact inverse of Algorithm 4's
    ``Prev`` whenever the decomposition chunks are all present, which the
    validity check enforces)."""
    if isinstance(operator, BasicDPOperator):
        return jp + k
    if isinstance(operator, GpuChunkDPOperator):
        dec = GpuChunkDPOperator.greedy_decompose(k)
        if dec is None:
            return None
        counts = operator.decode(jp)
        new_counts = tuple(x + y for x, y in zip(counts, dec))
        # guard the mixed radix: digit overflow would alias another state
        if any(x > n for x, n in zip(new_counts, operator.max_counts)):
            return None
        return operator.encode(new_counts)  # type: ignore[arg-type]
    raise TypeError(f"unknown operator {type(operator)!r}")


def brute_force_arrange(
    tasks: Sequence[DPTask],
    total_units: int,
    feasible: Optional[Callable[[Sequence[int]], bool]] = None,
) -> Optional[DPResult]:
    """Exhaustive reference for property tests (small instances only)."""
    best: Optional[DPResult] = None

    def rec(i: int, used: int, alloc: List[int], total: float) -> None:
        nonlocal best
        if i == len(tasks):
            if feasible is not None and not feasible(alloc):
                return
            if best is None or total < best.total_duration:
                best = DPResult(
                    total,
                    {t.name: a for t, a in zip(tasks, alloc)},
                    {
                        t.name: t.durations[t.units.index(a)]
                        for t, a in zip(tasks, alloc)
                    },
                )
            return
        for k, dur in zip(tasks[i].units, tasks[i].durations):
            if used + k > total_units:
                continue
            alloc.append(k)
            rec(i + 1, used + k, alloc, total + dur)
            alloc.pop()

    rec(0, 0, [], 0.0)
    return best
