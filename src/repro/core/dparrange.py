"""Topology-agnostic DPArrange (paper Appendix B, Algorithms 3 & 4).

Given a set of *scalable* candidate actions, their supported unit sets
``S_i`` and per-allocation durations ``T_i(k)``, DPArrange finds the
discrete allocation minimizing the total execution time subject to the
resource's **topology**, abstracted behind a DP *operator* providing
``Start / End / Prev / IsValid`` primitives:

* :class:`BasicDPOperator` — fungible units (CPU cores within a node,
  concurrency slots): state = units consumed so far.
* :class:`GpuChunkDPOperator` — power-of-two chunk topology (paper
  Algorithm 4): state = mixed-radix-encoded counts of consumed chunks of
  sizes {1, 2, 4, 8}; ``Prev`` greedily decomposes an allocation into
  chunks from largest to smallest.  Where the paper bounds states by
  fixed maximum chunk counts ``(N1, N2, N4, N8)``, we additionally accept
  an exact feasibility callback from the chunk allocator (buddy-splitting
  aware) — the operator interface the paper prescribes, with a sharper
  validity test.  The same operator serves the TPU-slice adaptation
  (ICI-contiguous 1/2/4/8-chip slices), demonstrating topology-agnosticism.

Deviation note: Algorithm 3 line 25 returns ``dp[m][n]`` (exactly-n
consumption).  With discrete unit sets an exact-n composition may not
exist (e.g. sets {1,4}x2, n=7), so we return the best *feasible* final
state ``argmin_j dp[m][j]`` — identical when exact-n is feasible, and
well-defined otherwise.

Dense fast path (PR 2)
----------------------
The original hash-map DP (kept below as :func:`dp_arrange_ref` /
:func:`dp_arrange_prefixes_ref`, the property-test reference) spends the
scheduler's whole hot-path budget on Python dict traffic.  The dense
path factors the topology out of the inner loop entirely:

1. each operator exports a precomputed **transition table**
   (:meth:`DPOperator.transition_table`): for every distinct unit choice
   ``k``, an int array ``next[k_idx, state] -> next_state`` with ``-1``
   as the invalid sentinel, plus a per-state validity mask.
   :class:`BasicDPOperator` is a trivial shift; :class:`GpuChunkDPOperator`
   enumerates its mixed-radix state space once per free-chunk
   configuration (callers cache the table on the owning manager's
   ``dp_cache_key``, which captures exactly the state the table reads);
2. :func:`dp_arrange_prefixes` then runs each task row as one vectorized
   scatter-min over ``(states x choices)`` — NumPy by default, with a
   jitted ``jax.lax.scan`` + ``segment_min`` path behind
   ``backend="jax"`` for large state spaces — emitting every prefix
   objective and a dense backtrace in a single pass.

The dense rows visit exactly the reachable-state sums the reference DP
visits (same float64 additions, same min over the same multisets), so
objectives are **bit-identical**; only argmin tie-breaking (and hence
the reported, equally-optimal allocation) may differ.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple

try:  # the dense fast path degrades to the dict reference without numpy
    import numpy as np
except ImportError:  # pragma: no cover - numpy is a core dependency
    np = None  # type: ignore[assignment]

INF = math.inf

#: Largest dense state-space an operator will enumerate; beyond it the
#: caller falls back to the sparse dict reference (which only visits
#: reachable states).  Overridable for tests / huge pools.
DENSE_STATE_LIMIT = int(os.environ.get("REPRO_DP_DENSE_STATE_LIMIT", 200_000))

#: Default dense backend ("numpy" | "jax").  The jax path is opt-in: it
#: pays a one-off jit compile per (m, K, S) shape and only wins on large
#: state spaces; it runs in float64 (via ``jax.experimental.enable_x64``)
#: so its objectives stay bit-identical to the reference.
DENSE_BACKEND = os.environ.get("REPRO_DP_BACKEND", "numpy")

_AUTO = object()  # sentinel: "build the transition table yourself"


@dataclass(frozen=True)
class DPTask:
    """One scalable candidate: supported unit set + duration model."""

    name: str
    units: Tuple[int, ...]  # S_i, sorted ascending
    durations: Tuple[float, ...]  # T_i(k) for each k in units


@dataclass
class TransitionTable:
    """Precomputed dense transition structure of one operator state.

    ``next[k_index[k], j]`` is the state reached from ``j`` by allocating
    ``k`` units, or ``-1`` when the transition is invalid (target out of
    topology bounds, infeasible under the operator's validity test, or
    ``k`` not decomposable).  ``valid[j]`` is the operator's ``IsValid``
    over the full dense state space; ``valid[0]`` gates the DP's start
    state.  A table is immutable and pure: it must be rebuilt (or
    re-fetched under a changed cache key) whenever the operator's backing
    resource state changes — managers guarantee this by keying cached
    tables on ``dp_cache_key``.
    """

    num_states: int
    ks: Tuple[int, ...]
    k_index: Dict[int, int]
    next: "np.ndarray"  # (len(ks), num_states) int64, -1 = invalid
    valid: "np.ndarray"  # (num_states,) bool
    shift: bool = False  # fungible-unit shift topology (BasicDPOperator)

    @property
    def start_valid(self) -> bool:
        return bool(self.valid[0])

    def covers(self, units: Sequence[int]) -> bool:
        return all(k in self.k_index for k in units)


class DPOperator:
    """Paper's "Basic DP Operator" interface (Algorithm 3 requirements).

    Dense contract (PR 2): operators that can enumerate their state
    space additionally implement :meth:`transition_table`, returning a
    :class:`TransitionTable` over the distinct unit choices ``ks`` (or
    ``None`` when enumeration is unsupported / over ``limit`` states, in
    which case callers use the sparse reference DP).  The table must
    agree exactly with ``prev``/``is_valid``: ``next[k, j] == j'`` iff
    ``is_valid(j')`` and ``prev(j', k) == j`` under greedy decomposition.
    """

    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        raise NotImplementedError

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        """Largest state index worth visiting."""
        raise NotImplementedError

    def prev(self, j: int, k: int) -> Optional[int]:
        """Predecessor state before allocating ``k`` units; None if invalid."""
        raise NotImplementedError

    def is_valid(self, j: int) -> bool:
        raise NotImplementedError

    def transition_table(
        self, ks: Sequence[int], limit: Optional[int] = None
    ) -> Optional[TransitionTable]:
        """Dense ``state x unit-choice -> next-state`` export; None = use
        the sparse reference DP.  ``limit`` caps the enumerated state
        space (default: module-level ``DENSE_STATE_LIMIT``, resolved at
        call time so tests/operators can tighten it)."""
        return None


class BasicDPOperator(DPOperator):
    """Fungible-unit topology: state ``j`` = units consumed so far."""

    def __init__(self, total_units: int) -> None:
        self.total_units = int(total_units)

    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        return sum(min(s) for s in unit_sets)

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        return min(self.total_units, sum(max(s) for s in unit_sets))

    def prev(self, j: int, k: int) -> Optional[int]:
        p = j - k
        return p if p >= 0 else None

    def is_valid(self, j: int) -> bool:
        return 0 <= j <= self.total_units

    def transition_table(
        self, ks: Sequence[int], limit: Optional[int] = None
    ) -> Optional[TransitionTable]:
        if np is None:
            return None
        num_states = self.total_units + 1
        if num_states > (DENSE_STATE_LIMIT if limit is None else limit):
            return None
        ks = tuple(sorted(set(int(k) for k in ks)))
        states = np.arange(num_states, dtype=np.int64)
        nxt = np.empty((len(ks), num_states), dtype=np.int64)
        for i, k in enumerate(ks):
            tgt = states + k
            nxt[i] = np.where(tgt <= self.total_units, tgt, -1)
        return TransitionTable(
            num_states=num_states,
            ks=ks,
            k_index={k: i for i, k in enumerate(ks)},
            next=nxt,
            valid=np.ones(num_states, dtype=bool),
            shift=True,
        )


class GpuChunkDPOperator(DPOperator):
    """Paper Algorithm 4: chunk-count states over sizes {1, 2, 4, 8}.

    State ``(a, b, c, d)`` counts *consumed* chunks of sizes 1/2/4/8,
    linearized with mixed-radix encoding (collision-free, finite).
    ``feasible`` — supplied by the chunk allocator — answers whether the
    current free-chunk configuration can yield that consumption multiset
    (buddy splitting allowed).  ``feasible`` must be **pure over the
    free-chunk snapshot the operator was built from** (the GPU manager
    closes it over a snapshot) so that :meth:`transition_table` output is
    cacheable under the manager's ``dp_cache_key``.
    """

    SIZES = (1, 2, 4, 8)

    def __init__(
        self,
        max_counts: Tuple[int, int, int, int],
        feasible: Optional[Callable[[Tuple[int, int, int, int]], bool]] = None,
        total_devices: Optional[int] = None,
    ) -> None:
        self.max_counts = tuple(int(n) for n in max_counts)
        self._radix = tuple(n + 1 for n in self.max_counts)
        self.total_devices = total_devices
        self._feasible = feasible
        # memoize feasibility — the DP revisits states heavily
        if feasible is not None:
            self._feasible = lru_cache(maxsize=None)(feasible)

    # -- mixed-radix encoding (Algorithm 4 Encode/Decode) -----------------
    def encode(self, counts: Tuple[int, int, int, int]) -> int:
        a, b, c, d = counts
        r1, r2, r4, _ = self._radix
        return a + r1 * (b + r2 * (c + r4 * d))

    def decode(self, j: int) -> Tuple[int, int, int, int]:
        r1, r2, r4, _ = self._radix
        a = j % r1
        j //= r1
        b = j % r2
        j //= r2
        c = j % r4
        j //= r4
        return (a, b, c, j)

    @staticmethod
    def greedy_decompose(k: int) -> Optional[Tuple[int, int, int, int]]:
        """Decompose ``k`` devices into chunk counts, largest first."""
        if k <= 0:
            return None
        counts = [0, 0, 0, 0]
        need = k
        for idx in (3, 2, 1, 0):
            size = GpuChunkDPOperator.SIZES[idx]
            counts[idx] = need // size
            need -= counts[idx] * size
        if need:
            return None
        return tuple(counts)  # type: ignore[return-value]

    # -- operator primitives ----------------------------------------------
    def start(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        counts = [0, 0, 0, 0]
        for s in unit_sets:
            dec = self.greedy_decompose(min(s))
            if dec is None:
                return 0
            counts = [x + y for x, y in zip(counts, dec)]
        counts = [min(x, n) for x, n in zip(counts, self.max_counts)]
        return self.encode(tuple(counts))  # type: ignore[arg-type]

    def end(self, unit_sets: Sequence[Tuple[int, ...]]) -> int:
        r1, r2, r4, r8 = self._radix
        return r1 * r2 * r4 * r8 - 1

    def prev(self, j: int, k: int) -> Optional[int]:
        a, b, c, d = self.decode(j)
        need = k
        use_d = min(d, need // 8)
        need -= 8 * use_d
        use_c = min(c, need // 4)
        need -= 4 * use_c
        use_b = min(b, need // 2)
        need -= 2 * use_b
        use_a = min(a, need)
        need -= use_a
        if need > 0:
            return None  # not enough chunks in-state to satisfy k
        return self.encode((a - use_a, b - use_b, c - use_c, d - use_d))

    def is_valid(self, j: int) -> bool:
        counts = self.decode(j)
        if any(x < 0 or x > n for x, n in zip(counts, self.max_counts)):
            return False
        if self.total_devices is not None:
            used = sum(c * s for c, s in zip(counts, self.SIZES))
            if used > self.total_devices:
                return False
        if self._feasible is not None and not self._feasible(counts):
            return False
        return True

    def transition_table(
        self, ks: Sequence[int], limit: Optional[int] = None
    ) -> Optional[TransitionTable]:
        """Enumerate the full mixed-radix state space once.

        Cheap mask tests (radix bounds are implicit, ``total_devices`` is
        vectorized) prune the state set before the Python ``feasible``
        callback runs, so the callback only sees states that could hold
        devices at all.
        """
        if np is None:
            return None
        r1, r2, r4, r8 = self._radix
        num_states = r1 * r2 * r4 * r8
        if num_states > (DENSE_STATE_LIMIT if limit is None else limit):
            return None
        js = np.arange(num_states, dtype=np.int64)
        a = js % r1
        t = js // r1
        b = t % r2
        t //= r2
        c = t % r4
        d = t // r4
        valid = np.ones(num_states, dtype=bool)
        if self.total_devices is not None:
            valid &= (a + 2 * b + 4 * c + 8 * d) <= self.total_devices
        if self._feasible is not None:
            idx = np.flatnonzero(valid)
            feas = self._feasible  # lru-cached
            valid[idx] = np.fromiter(
                (
                    feas((int(a[j]), int(b[j]), int(c[j]), int(d[j])))
                    for j in idx
                ),
                dtype=bool,
                count=idx.size,
            )
        ks = tuple(sorted(set(int(k) for k in ks)))
        nxt = np.full((len(ks), num_states), -1, dtype=np.int64)
        for i, k in enumerate(ks):
            dec = self.greedy_decompose(k)
            if dec is None:
                continue
            na, nb, nc, nd = a + dec[0], b + dec[1], c + dec[2], d + dec[3]
            # guard the mixed radix: digit overflow would alias a state
            ok = (na < r1) & (nb < r2) & (nc < r4) & (nd < r8)
            tgt = na + r1 * (nb + r2 * (nc + r4 * nd))
            safe = np.where(ok, tgt, 0)
            ok &= valid[safe]
            nxt[i] = np.where(ok, tgt, -1)
        return TransitionTable(
            num_states=num_states,
            ks=ks,
            k_index={k: i for i, k in enumerate(ks)},
            next=nxt,
            valid=valid,
        )


@dataclass
class DPResult:
    total_duration: float
    allocation: Dict[str, int]  # task name -> units
    durations: Dict[str, float]  # task name -> T_i(k_i)


# ---------------------------------------------------------------------------
# Dense vectorized DP (the scheduler's fast path)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=8)
def _jax_compiled_scan(S: int):
    """One jitted scan kernel per state-space size (module-level cache so
    repeated DP calls reuse the traced/compiled XLA program; jax itself
    re-specializes per (m, K) input shape under the same jit object)."""
    import jax
    import jax.numpy as jnp

    def step(prev, inputs):
        nxt, durs = inputs
        cand = prev[None, :] + durs[:, None]
        seg = jnp.where(nxt >= 0, nxt, S)  # S = invalid dump bucket
        new = jax.ops.segment_min(
            cand.ravel(), seg.ravel(), num_segments=S + 1
        )[:S]
        return new, new

    def run(nxt_all, durs_all, v0):
        _, rows = jax.lax.scan(step, v0, (nxt_all, durs_all))
        return rows

    return jax.jit(run)


def _jax_value_rows(nxt_pad, durs_pad, start_valid, num_states):
    """All DP value rows via a jitted scan of segment-mins (float64)."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        v0 = jnp.full((num_states,), jnp.inf, dtype=jnp.float64)
        if start_valid:
            v0 = v0.at[0].set(0.0)
        rows = _jax_compiled_scan(num_states)(
            jnp.asarray(nxt_pad), jnp.asarray(durs_pad, dtype=jnp.float64), v0
        )
        out = np.array(rows, dtype=np.float64)  # copy: jax buffers are read-only
    # segment_min's identity for float64 is +inf, so unreached states are
    # already inf; normalize defensively anyway.
    out[out > 1e300] = INF
    return out


def dp_arrange_prefixes_dense(
    tasks: Sequence[DPTask],
    operator: DPOperator,
    table: Optional[TransitionTable] = None,
    backend: Optional[str] = None,
    weights: Optional[Sequence[float]] = None,
) -> Optional[List[Optional[DPResult]]]:
    """Vectorized :func:`dp_arrange_prefixes_ref`: one scatter-min per
    task row over the operator's dense transition table.

    Returns ``None`` when the dense path is unavailable (no numpy, the
    operator cannot export a table, or the table does not cover the task
    unit sets) — callers fall back to the sparse reference.  Otherwise
    the result is objective-identical to the reference: the same float64
    sums are formed and minimized, so every prefix's ``total_duration``
    matches bit-for-bit (ties may back-track to a different, equally
    optimal allocation).

    ``weights`` (multi-tenant fairness): optional per-task multipliers —
    the objective becomes ``sum_i w_i * T_i(k_i)`` so a heavy-weight
    tenant's completion time counts for more when trading allocations
    off, while the reported per-task ``durations`` stay the TRUE
    durations (callers feed them to the completion-time estimate).
    ``None`` is the unweighted paper objective, bit-identical to the
    pre-fairness code path.
    """
    if np is None:
        return None
    m = len(tasks)
    if table is None:
        ks = sorted({k for t in tasks for k in t.units})
        table = operator.transition_table(tuple(ks))
    if table is None or not table.covers([k for t in tasks for k in t.units]):
        return None
    S = table.num_states

    results: List[Optional[DPResult]] = [DPResult(0.0, {}, {})]
    value = np.full(S, INF)
    if table.start_valid:
        value[0] = 0.0

    # Optional jitted backend: compute all value rows in one scan, then
    # share the numpy backtrace below.  Heterogeneous unit-set sizes are
    # padded with invalid transitions (duration slot unused).
    backend = backend or DENSE_BACKEND
    jax_rows = None
    if backend == "jax" and m > 0:
        kmax = max(len(t.units) for t in tasks)
        nxt_pad = np.full((m, kmax, S), -1, dtype=np.int64)
        durs_pad = np.zeros((m, kmax), dtype=np.float64)
        for i, task in enumerate(tasks):
            kidx = [table.k_index[k] for k in task.units]
            nxt_pad[i, : len(kidx)] = table.next[kidx]
            durs_pad[i, : len(kidx)] = task.durations
            if weights is not None:
                durs_pad[i, : len(kidx)] *= float(weights[i])
        try:
            jax_rows = _jax_value_rows(nxt_pad, durs_pad, table.start_valid, S)
        except ImportError:
            jax_rows = None

    backptrs: List["np.ndarray"] = []  # per row: state -> flat (choice*S+prev)
    for i, task in enumerate(tasks):
        kidx = [table.k_index[k] for k in task.units]
        nxt = table.next[kidx]  # (K, S)
        durs = np.asarray(task.durations, dtype=np.float64)
        if weights is not None:
            durs = durs * float(weights[i])  # objective-side only
        cand = value[None, :] + durs[:, None]  # (K, S)
        if jax_rows is not None:
            new = jax_rows[i]
        elif table.shift:
            # fungible units: transition k is a pure shift — use sliced
            # minimums instead of a scatter (same sums, much faster)
            new = np.full(S, INF)
            for ci, k in enumerate(task.units):
                if k < S:
                    np.minimum(new[k:], cand[ci, : S - k], out=new[k:])
        else:
            ok = (nxt >= 0) & np.isfinite(cand)
            new = np.full(S, INF)
            if ok.any():
                np.minimum.at(new, nxt[ok], cand[ok])
        # dense backtrace: the first (choice-major) contributor achieving
        # each state's minimum.  Exact float equality is sound — ``new``
        # values are drawn from ``cand`` verbatim.
        safe = np.where(nxt >= 0, nxt, 0)
        ach = (nxt >= 0) & (cand == new[safe])
        flat = np.flatnonzero(ach.ravel())  # ascending (choice-major)
        bp = np.full(S, -1, dtype=np.int64)
        bp[nxt.ravel()[flat][::-1]] = flat[::-1]  # smallest index wins
        backptrs.append(bp)
        value = new

        finite = np.isfinite(new)
        if not finite.any():
            results.append(None)
            continue
        best_state = int(np.argmin(new))
        alloc: Dict[str, int] = {}
        durs_out: Dict[str, float] = {}
        state = best_state
        feasible = True
        for t in range(i, -1, -1):
            f = int(backptrs[t][state])
            if f < 0:
                feasible = False
                break
            choice, state = divmod(f, S)
            tk = tasks[t]
            alloc[tk.name] = tk.units[choice]
            durs_out[tk.name] = tk.durations[choice]
        results.append(
            DPResult(float(new[best_state]), alloc, durs_out) if feasible else None
        )
    return results


#: Cost-model constants for the dense-vs-sparse dispatch, in units of
#: "one sparse dict transition" (~0.2us of Python).  A dense task row
#: costs a fixed ~10 numpy calls (DENSE_ROW_OVERHEAD_OPS) plus work
#: linear in the full (choices x states) sweep (DENSE_CELL_COST each,
#: cheap vectorized element ops).  Both paths produce bit-identical
#: objectives, so the dispatch is purely a latency decision.
DENSE_ROW_OVERHEAD_OPS = 90
DENSE_CELL_COST = 0.07


def _dense_worthwhile(tasks: Sequence[DPTask], table: TransitionTable) -> bool:
    """Predict whether the vectorized sweep beats the sparse dict DP.

    The sparse DP touches ``reachable_states x choices`` per row, where
    reachability is bounded by the product of choice counts and (for the
    shift topology) by the span of attainable unit sums; the dense sweep
    always pays the full ``choices x states`` row.  Small instances
    (few tasks against a large pool) are faster sparse."""
    S = table.num_states
    reach = 1
    span = 1
    ref_ops = 0
    dense_ops = 0.0
    for t in tasks:
        K = len(t.units)
        ref_ops += reach * K
        dense_ops += DENSE_ROW_OVERHEAD_OPS + DENSE_CELL_COST * K * S
        reach = min(S, reach * K)
        if table.shift:
            span += t.units[-1] - t.units[0]
            reach = min(reach, span)
    return ref_ops > dense_ops


def dp_arrange_prefixes(
    tasks: Sequence[DPTask],
    operator: DPOperator,
    table: object = _AUTO,
    backend: Optional[str] = None,
    weights: Optional[Sequence[float]] = None,
) -> List[Optional[DPResult]]:
    """DPResult for every prefix ``tasks[:i]`` (i = 0..m) in ONE DP pass.

    Greedy eviction (Alg. 1) always evicts the LAST candidate, so the
    objective of every kept-set it evaluates is a prefix of the same DP —
    one pass over the rows serves the whole eviction loop (this is what
    keeps the scheduler inside the paper's O(k n^2 m^2) bound).

    Dispatches to the dense vectorized path when the operator exports a
    transition table (``table``: pass a pre-built/cached
    :class:`TransitionTable`, or ``None`` to force the sparse reference)
    AND the instance is big enough for vectorization to pay
    (:func:`_dense_worthwhile`); otherwise runs
    :func:`dp_arrange_prefixes_ref`.  Both paths return bit-identical
    objectives.
    """
    if table is not None:
        resolved: Optional[TransitionTable]
        if table is _AUTO:
            ks = sorted({k for t in tasks for k in t.units})
            resolved = operator.transition_table(tuple(ks))
        else:
            resolved = table  # type: ignore[assignment]
        if resolved is not None and (
            backend == "jax" or _dense_worthwhile(tasks, resolved)
        ):
            dense = dp_arrange_prefixes_dense(
                tasks, operator, resolved, backend, weights=weights
            )
            if dense is not None:
                return dense
    return dp_arrange_prefixes_ref(tasks, operator, weights=weights)


def dp_arrange(
    tasks: Sequence[DPTask],
    operator: DPOperator,
    weights: Optional[Sequence[float]] = None,
) -> Optional[DPResult]:
    """Algorithm 3.  Returns None when even minimal allocation is infeasible.

    Uses the dense fast path when available and worthwhile (see
    :func:`dp_arrange_prefixes`); :func:`dp_arrange_ref` is the sparse
    dict-based reference."""
    if not tasks:
        return DPResult(0.0, {}, {})
    return dp_arrange_prefixes(tasks, operator, weights=weights)[-1]


# ---------------------------------------------------------------------------
# Sparse dict-based reference (the original implementation; property
# tests assert the dense path is objective-identical to it)
# ---------------------------------------------------------------------------


def dp_arrange_ref(
    tasks: Sequence[DPTask],
    operator: DPOperator,
    weights: Optional[Sequence[float]] = None,
) -> Optional[DPResult]:
    """Reference Algorithm 3 over a sparse dict of reachable states."""
    m = len(tasks)
    if m == 0:
        return DPResult(0.0, {}, {})
    unit_sets = [t.units for t in tasks]
    n = operator.end(unit_sets)
    if n < 0:
        return None

    # dp maps state -> best total duration for the first i tasks; we keep
    # two rolling rows plus a choice table for backtracking.
    prev_row: Dict[int, float] = {}
    start0 = 0
    if operator.is_valid(start0):
        prev_row[start0] = 0.0
    if not prev_row:
        return None
    choice: List[Dict[int, Tuple[int, int]]] = []  # [i] state -> (k, prev_state)

    for i, task in enumerate(tasks):
        w = None if weights is None else float(weights[i])
        cur_row: Dict[int, float] = {}
        cur_choice: Dict[int, Tuple[int, int]] = {}
        for jp, base in prev_row.items():
            for k, dur in zip(task.units, task.durations):
                # forward transition: state jp --(allocate k to task i)--> j
                j = _forward(operator, jp, k)
                if j is None or j > n or not operator.is_valid(j):
                    continue
                total = base + (dur if w is None else dur * w)
                if total < cur_row.get(j, INF):
                    cur_row[j] = total
                    cur_choice[j] = (k, jp)
        if not cur_row:
            return None
        prev_row = cur_row
        choice.append(cur_choice)

    best_state = min(prev_row, key=lambda s: prev_row[s])
    best = prev_row[best_state]

    # backtrack
    alloc: Dict[str, int] = {}
    durs: Dict[str, float] = {}
    state = best_state
    for i in range(m - 1, -1, -1):
        k, pstate = choice[i][state]
        alloc[tasks[i].name] = k
        kidx = tasks[i].units.index(k)
        durs[tasks[i].name] = tasks[i].durations[kidx]
        state = pstate
    return DPResult(best, alloc, durs)


def dp_arrange_prefixes_ref(
    tasks: Sequence[DPTask],
    operator: DPOperator,
    weights: Optional[Sequence[float]] = None,
) -> List[Optional[DPResult]]:
    """Reference prefix DP over sparse dict rows (see
    :func:`dp_arrange_prefixes` for the contract)."""
    m = len(tasks)
    results: List[Optional[DPResult]] = [DPResult(0.0, {}, {})]
    rows: List[Dict[int, float]] = [{0: 0.0} if operator.is_valid(0) else {}]
    choices: List[Dict[int, Tuple[int, int]]] = []
    unit_sets = [t.units for t in tasks]
    n = operator.end(unit_sets)
    for i, task in enumerate(tasks):
        w = None if weights is None else float(weights[i])
        prev_row = rows[-1]
        cur_row: Dict[int, float] = {}
        cur_choice: Dict[int, Tuple[int, int]] = {}
        for jp, base in prev_row.items():
            for k, dur in zip(task.units, task.durations):
                j = _forward(operator, jp, k)
                if j is None or j > n or not operator.is_valid(j):
                    continue
                total = base + (dur if w is None else dur * w)
                if total < cur_row.get(j, INF):
                    cur_row[j] = total
                    cur_choice[j] = (k, jp)
        rows.append(cur_row)
        choices.append(cur_choice)
        if not cur_row:
            results.append(None)
            continue
        best_state = min(cur_row, key=lambda s: cur_row[s])
        alloc: Dict[str, int] = {}
        durs: Dict[str, float] = {}
        state = best_state
        feasible = True
        for t in range(i, -1, -1):
            if state not in choices[t]:
                feasible = False
                break
            k, pstate = choices[t][state]
            alloc[tasks[t].name] = k
            durs[tasks[t].name] = tasks[t].durations[tasks[t].units.index(k)]
            state = pstate
        results.append(
            DPResult(cur_row[best_state], alloc, durs) if feasible else None
        )
    return results


def _forward(operator: DPOperator, jp: int, k: int) -> Optional[int]:
    """Invert ``Prev``: the state reached from ``jp`` by allocating ``k``.

    For the basic operator this is ``jp + k``; for the chunk operator we
    add the greedy decomposition (the exact inverse of Algorithm 4's
    ``Prev`` whenever the decomposition chunks are all present, which the
    validity check enforces)."""
    if isinstance(operator, BasicDPOperator):
        return jp + k
    if isinstance(operator, GpuChunkDPOperator):
        dec = GpuChunkDPOperator.greedy_decompose(k)
        if dec is None:
            return None
        counts = operator.decode(jp)
        new_counts = tuple(x + y for x, y in zip(counts, dec))
        # guard the mixed radix: digit overflow would alias another state
        if any(x > n for x, n in zip(new_counts, operator.max_counts)):
            return None
        return operator.encode(new_counts)  # type: ignore[arg-type]
    raise TypeError(f"unknown operator {type(operator)!r}")


def brute_force_arrange(
    tasks: Sequence[DPTask],
    total_units: int,
    feasible: Optional[Callable[[Sequence[int]], bool]] = None,
) -> Optional[DPResult]:
    """Exhaustive reference for property tests (small instances only)."""
    best: Optional[DPResult] = None

    def rec(i: int, used: int, alloc: List[int], total: float) -> None:
        nonlocal best
        if i == len(tasks):
            if feasible is not None and not feasible(alloc):
                return
            if best is None or total < best.total_duration:
                best = DPResult(
                    total,
                    {t.name: a for t, a in zip(tasks, alloc)},
                    {
                        t.name: t.durations[t.units.index(a)]
                        for t, a in zip(tasks, alloc)
                    },
                )
            return
        for k, dur in zip(tasks[i].units, tasks[i].durations):
            if used + k > total_units:
                continue
            alloc.append(k)
            rec(i + 1, used + k, alloc, total + dur)
            alloc.pop()

    rec(0, 0, [], 0.0)
    return best
