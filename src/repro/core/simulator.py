"""Discrete-event simulation engine.

ARL-Tangram's control plane is clock-agnostic: the same scheduler and
managers run against a :class:`SimClock` (benchmarks; reproduces the
paper's figures from trace-parameterized workloads) or a
:class:`RealClock` (live mode; the end-to-end example executes real JAX
work on a thread pool).  The engine is a plain binary-heap event loop.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class Clock:
    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class RealClock(Clock):
    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


class FrozenClock(Clock):
    """A clock pinned at one instant — the governing clock of manager
    snapshots rebuilt from the wire.  Planning happens at a fixed
    virtual ``now`` (no event callback runs while plans are
    outstanding), so a remote snapshot's time-dependent state (quota
    refills) must read exactly the instant the snapshot was taken."""

    def __init__(self, at: float) -> None:
        self._at = float(at)

    def now(self) -> float:
        return self._at


@dataclass(order=True)
class _Event:
    when: float
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


#: Relative tolerance for "same timestamp" comparisons.  Coalesced
#: same-instant events reach the heap through different float-sum paths
#: (submit_time + overheads vs finish_time - duration, ...), so their
#: timestamps can disagree by a few ulps — at virtual times around 1e6 s
#: one ulp is ~2.3e-10, far above any absolute 1e-12 guard.  Long
#: multi-tenant runs hit exactly this ("time went backwards" on events
#: that are logically simultaneous); comparing with an epsilon scaled by
#: the clock's magnitude keeps the guard meaningful at every time scale.
TIME_REL_EPS = 1e-9


def _time_tolerance(now: float) -> float:
    return TIME_REL_EPS * max(1.0, abs(now))


class SimClock(Clock):
    """Virtual time advanced by :class:`EventLoop`."""

    def __init__(self) -> None:
        self._now = 0.0

    def now(self) -> float:
        return self._now

    def _advance(self, t: float) -> None:
        if t < self._now - _time_tolerance(self._now):
            raise RuntimeError(f"time went backwards: {t} < {self._now}")
        self._now = max(self._now, t)


class EventLoop:
    """Deterministic discrete-event loop over a :class:`SimClock`."""

    def __init__(self, clock: Optional[SimClock] = None) -> None:
        self.clock = clock or SimClock()
        self._heap: List[_Event] = []
        self._seq = itertools.count()

    def call_at(self, when: float, callback: Callable[[], None]) -> _Event:
        if when < self.clock.now() - _time_tolerance(self.clock.now()):
            raise ValueError(f"cannot schedule in the past: {when} < {self.clock.now()}")
        ev = _Event(when=when, seq=next(self._seq), callback=callback)
        heapq.heappush(self._heap, ev)
        return ev

    def call_after(self, delay: float, callback: Callable[[], None]) -> _Event:
        return self.call_at(self.clock.now() + max(0.0, delay), callback)

    def cancel(self, ev: _Event) -> None:
        ev.cancelled = True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> float:
        """Drain events (optionally up to virtual time ``until``)."""
        n = 0
        while self._heap:
            if until is not None and self._heap[0].when > until:
                self.clock._advance(until)
                break
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.clock._advance(ev.when)
            ev.callback()
            n += 1
            if n >= max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")
        return self.clock.now()

    def pending(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)


class Future:
    """Minimal future usable from both sim and live modes."""

    def __init__(self) -> None:
        self._done = threading.Event()
        self._result: object = None
        self._exc: Optional[BaseException] = None
        self._callbacks: List[Callable[["Future"], None]] = []

    def set_result(self, value: object) -> None:
        self._result = value
        self._done.set()
        for cb in self._callbacks:
            cb(self)

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._done.set()
        for cb in self._callbacks:
            cb(self)

    def add_done_callback(self, cb: Callable[["Future"], None]) -> None:
        if self._done.is_set():
            cb(self)
        else:
            self._callbacks.append(cb)

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> object:
        if not self._done.wait(timeout):
            raise TimeoutError("future not resolved")
        if self._exc is not None:
            raise self._exc
        return self._result
