"""ARL-Tangram system facade (paper §3).

The standardized execution cycle:

1. **Action submission** — the RL framework (or a workload generator)
   calls :meth:`Tangram.submit`; the action enters the unified queue.
2. **Unified formulation & queuing** — actions arrive already normalized
   (:mod:`repro.core.action`); FCFS order is their submission order.
3. **Elastic scheduling** — every submission/completion event triggers a
   scheduling round (:mod:`repro.core.scheduler`) against live manager
   state.
4. **Action execution** — selected actions acquire *all* their
   vectorized resources (rollback on partial failure), run for
   ``duration(units) + system overhead``, then
5. **Transmit & observation** — resources are released, telemetry and
   duration history are updated, the action's future resolves, and the
   next round fires.

Since the event-driven refactor the mechanics live in
:class:`repro.core.orchestrator.Orchestrator` (partitioned queues,
coalesced rounds, dirty tracking, the action lifecycle); ``Tangram``
is the paper-facing facade that wires an
:class:`~repro.core.scheduler.ElasticScheduler` policy in by default
and keeps the historical ``scheduler`` attribute name.  The facade is
clock-agnostic: driven by a DES :class:`EventLoop` for the benchmarks,
or stepped with real threads in live mode (examples).
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.base import ResourceManager
from repro.core.orchestrator import SCHED_TICK_S, Orchestrator, SchedulingPolicy
from repro.core.simulator import EventLoop

__all__ = ["Tangram", "SCHED_TICK_S"]


class Tangram(Orchestrator):
    def __init__(
        self,
        managers: Dict[str, ResourceManager],
        loop: Optional[EventLoop] = None,
        scheduler: Optional[SchedulingPolicy] = None,
        charge_real_sched_latency: bool = False,
        incremental: bool = True,
        fair_share: Optional[FairSharePolicy] = None,
        shards: Optional[int] = None,
    ) -> None:
        super().__init__(
            managers,
            loop=loop,
            policy=scheduler,
            charge_real_sched_latency=charge_real_sched_latency,
            incremental=incremental,
            fair_share=fair_share,
            shards=shards,
        )

    # historical name for the policy slot (pre-refactor callers assign a
    # configured ElasticScheduler here after construction)
    @property
    def scheduler(self) -> SchedulingPolicy:
        return self.policy

    @scheduler.setter
    def scheduler(self, policy: SchedulingPolicy) -> None:
        self.policy = policy
        if getattr(policy, "cache_dp", False) is None:
            policy.cache_dp = self.incremental
        if (
            self.fair_share is not None
            and hasattr(policy, "fair_share")
            and policy.fair_share is None
        ):
            policy.fair_share = self.fair_share
