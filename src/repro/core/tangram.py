"""ARL-Tangram system facade (paper §3).

The standardized execution cycle:

1. **Action submission** — the RL framework (or a workload generator)
   calls :meth:`Tangram.submit`; the action enters the unified queue.
2. **Unified formulation & queuing** — actions arrive already normalized
   (:mod:`repro.core.action`); FCFS order is their submission order.
3. **Elastic scheduling** — every submission/completion event triggers a
   scheduling round (:mod:`repro.core.scheduler`) against live manager
   state.
4. **Action execution** — selected actions acquire *all* their
   vectorized resources (rollback on partial failure), run for
   ``duration(units) + system overhead``, then
5. **Transmit & observation** — resources are released, telemetry and
   duration history are updated, the action's future resolves, and the
   next round fires.

The facade is clock-agnostic: driven by a DES :class:`EventLoop` for the
benchmarks, or stepped with real threads in live mode (examples).
"""

from __future__ import annotations

import math
import time
from typing import Dict, List, Optional, Sequence

from repro.core.action import Action, ActionState, DurationHistory
from repro.core.managers.base import Allocation, ResourceManager
from repro.core.scheduler import Decision, ElasticScheduler
from repro.core.simulator import EventLoop, Future
from repro.core.telemetry import ActionRecord, Telemetry

# Decision latency charged per scheduling round when not measuring the
# real wall clock (Table 1 shows sub-3% system overhead on CPU workloads).
SCHED_TICK_S = 0.0005


class Tangram:
    def __init__(
        self,
        managers: Dict[str, ResourceManager],
        loop: Optional[EventLoop] = None,
        scheduler: Optional[ElasticScheduler] = None,
        charge_real_sched_latency: bool = False,
    ) -> None:
        self.loop = loop or EventLoop()
        self.history = DurationHistory()
        self.scheduler = scheduler or ElasticScheduler(history=self.history)
        self.managers = managers
        self.telemetry = Telemetry()
        self.charge_real_sched_latency = charge_real_sched_latency
        self._waiting: List[Action] = []
        self._executing: List[Action] = []
        self._futures: Dict[int, Future] = {}
        self._allocs: Dict[int, List[Allocation]] = {}
        self._tick_scheduled = False

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def submit(self, action: Action, delay: float = 0.0) -> Future:
        fut = Future()
        self._futures[action.uid] = fut

        def _enqueue() -> None:
            action.submit_time = self.loop.clock.now()
            action.state = ActionState.QUEUED
            self._waiting.append(action)
            self._request_tick()

        self.loop.call_after(delay, _enqueue)
        return fut

    def trajectory_start(self, trajectory_id: str, metadata: Optional[dict] = None) -> None:
        for m in self.managers.values():
            m.trajectory_start(trajectory_id, metadata or {})

    def trajectory_end(self, trajectory_id: str) -> None:
        for m in self.managers.values():
            m.trajectory_end(trajectory_id)

    def run(self, until: Optional[float] = None) -> float:
        return self.loop.run(until=until)

    @property
    def now(self) -> float:
        return self.loop.clock.now()

    # ------------------------------------------------------------------
    # scheduling rounds
    # ------------------------------------------------------------------
    def _request_tick(self) -> None:
        if self._tick_scheduled:
            return
        self._tick_scheduled = True
        self.loop.call_after(0.0, self._tick)

    def _tick(self) -> None:
        self._tick_scheduled = False
        if not self._waiting:
            return
        for m in self.managers.values():
            if hasattr(m, "set_time"):
                m.set_time(self.now)

        t0 = time.perf_counter()
        result = self.scheduler.schedule(
            self._waiting, self._executing, self.managers, self.now
        )
        sched_wall = time.perf_counter() - t0
        self.telemetry.sched_invocations += 1
        self.telemetry.sched_wall_s += sched_wall
        sched_overhead = sched_wall if self.charge_real_sched_latency else SCHED_TICK_S

        launched = False
        for decision in result.decisions:
            if self._launch(decision, sched_overhead):
                launched = True
        # quota refills may unblock queued actions even without completions
        if self._waiting and not launched:
            wake = min(
                (
                    m.time_to_next_refill()
                    for m in self.managers.values()
                    if hasattr(m, "time_to_next_refill")
                ),
                default=math.inf,
            )
            if math.isfinite(wake) and wake > 0:
                self.loop.call_after(wake + 1e-6, self._request_tick)

    def _launch(self, decision: Decision, sched_overhead: float) -> bool:
        action = decision.action
        allocs: List[Allocation] = []
        for rtype in sorted(decision.units):
            manager = self.managers.get(rtype)
            if manager is None:
                continue
            alloc = manager.try_allocate(action, decision.units[rtype])
            if alloc is None:
                for a in allocs:  # rollback partial acquisition
                    self.managers[a.rtype].release(action, a)
                return False
            allocs.append(alloc)

        self._waiting.remove(action)
        self._executing.append(action)
        self._allocs[action.uid] = allocs
        action.state = ActionState.RUNNING
        action.start_time = self.now
        overhead = sched_overhead + sum(a.overhead for a in allocs)
        action.sys_overhead = overhead

        key_units = decision.units.get(action.key_resource or "", None)
        duration = self._duration_of(action, key_units)
        action.finish_time = self.now + overhead + duration
        self.loop.call_at(action.finish_time, lambda: self._complete(action, duration))
        return True

    def _duration_of(self, action: Action, key_units: Optional[int]) -> float:
        if action.duration_sampler is not None:
            return action.duration_sampler(key_units or 1)
        d = action.get_dur(key_units) if key_units is not None else action.get_dur()
        if math.isnan(d):
            d = self.history.estimate(action)
        return d

    def _complete(self, action: Action, duration: float) -> None:
        self._executing.remove(action)
        allocs = self._allocs.pop(action.uid, [])
        for alloc in allocs:
            self.managers[alloc.rtype].release(action, alloc)
        action.state = ActionState.DONE
        self.history.observe(action.name, duration)
        units = {a.rtype: a.units for a in allocs}
        self.telemetry.record(
            ActionRecord(
                name=action.name,
                task_id=action.task_id,
                trajectory_id=action.trajectory_id,
                submit=action.submit_time,
                start=action.start_time,
                finish=action.finish_time,
                sys_overhead=action.sys_overhead,
                units=units,
            )
        )
        fut = self._futures.pop(action.uid, None)
        if fut is not None:
            fut.set_result(duration)
        self._request_tick()

    # ------------------------------------------------------------------
    def queue_depth(self) -> int:
        return len(self._waiting)

    def in_flight(self) -> int:
        return len(self._executing)
