"""GRPO (Group Relative Policy Optimization, Shao et al. 2024).

The paper's workloads (§6.1) train with GRPO: for each prompt a *group*
of G trajectories is rolled out; advantages are the group-normalized
rewards; the policy gradient uses a PPO-style clipped ratio against the
rollout-time log-probs, plus a KL penalty to the reference policy.

Rewards come from external resources (test execution on CPUs, reward
models on GPUs) — in this repo those invocations are ARL-Tangram
actions (see rl/driver.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi
from repro.models.layers import logits_fn
from repro.models.transformer import embed_tokens, forward
from repro.sharding.rules import Rules
from repro.training.optimizer import AdamWConfig, adamw_update
from repro.training.train_step import TrainState


def group_advantages(rewards: jax.Array) -> jax.Array:
    """rewards [B, G] -> group-normalized advantages [B, G]."""
    mean = jnp.mean(rewards, axis=1, keepdims=True)
    std = jnp.std(rewards, axis=1, keepdims=True)
    return (rewards - mean) / (std + 1e-6)


def token_logprobs(
    params: dict, tokens: jax.Array, api: ModelApi, rules: Optional[Rules] = None
) -> jax.Array:
    """Log-prob of each realized next token; [N, S-1]."""
    cfg = api.cfg
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h, _ = forward(params, x, pos, cfg, rules)
    logits = logits_fn(params, h[:, :-1, :], cfg)  # [N, S-1, V] f32
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]


def grpo_loss(
    params: dict,
    batch: Dict[str, jax.Array],
    api: ModelApi,
    rules: Optional[Rules] = None,
    clip_eps: float = 0.2,
    kl_coef: float = 0.02,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens [N,S], mask [N,S-1] (1 on generated positions),
    advantages [N], old_logp [N,S-1], ref_logp [N,S-1]."""
    tokens = batch["tokens"]
    mask = batch["mask"].astype(jnp.float32)
    adv = batch["advantages"][:, None]  # [N,1] broadcast over positions
    logp = token_logprobs(params, tokens, api, rules)
    ratio = jnp.exp(logp - batch["old_logp"])
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pg = -jnp.minimum(unclipped, clipped)
    # k3 KL estimator (non-negative, unbiased-ish): exp(d) - d - 1
    d = batch["ref_logp"] - logp
    kl = jnp.exp(d) - d - 1.0
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    pg_loss = jnp.sum(pg * mask) / denom
    kl_loss = jnp.sum(kl * mask) / denom
    loss = pg_loss + kl_coef * kl_loss
    return loss, {
        "pg_loss": pg_loss,
        "kl": kl_loss,
        "ratio_mean": jnp.sum(ratio * mask) / denom,
    }


def make_grpo_step(api: ModelApi, opt_cfg: AdamWConfig, rules: Optional[Rules] = None):
    def step(state: TrainState, batch: Dict[str, jax.Array]):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: grpo_loss(p, batch, api, rules), has_aux=True
        )(state.params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt
        )
        return TrainState(new_params, new_opt), {"loss": loss, **metrics, **opt_metrics}

    return step
