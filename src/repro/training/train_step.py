"""Jittable train step(s): LM pre-training and the pjit wiring helpers."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi
from repro.sharding.rules import Rules
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    adamw_update,
    init_adamw,
)


class TrainState:
    """Lightweight pytree container (registered below)."""

    def __init__(self, params: Any, opt: AdamWState):
        self.params = params
        self.opt = opt

    def tree_flatten(self):
        return (self.params, self.opt), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten
)


def init_train_state(api: ModelApi, key: jax.Array) -> TrainState:
    params = api.init(key)
    return TrainState(params, init_adamw(params))


def make_train_step(
    api: ModelApi,
    opt_cfg: AdamWConfig,
    rules: Optional[Rules] = None,
):
    """Returns ``train_step(state, batch) -> (state, metrics)``."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss_fn(params):
            loss, metrics = api.loss_fn(params, batch, rules)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt
        )
        out = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(new_params, new_opt), out

    return train_step


def make_grad_accum_train_step(
    api: ModelApi,
    opt_cfg: AdamWConfig,
    accum_steps: int,
    rules: Optional[Rules] = None,
):
    """Microbatched step: batch leading dim = [accum, micro_batch, ...]."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss_fn(params, micro):
            loss, _ = api.loss_fn(params, micro, rules)
            return loss

        def acc_body(carry, micro):
            gsum, lsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(state.params, micro)
            gsum = jax.tree.map(jnp.add, gsum, grads)
            return (gsum, lsum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), batch)
        grads = jax.tree.map(lambda g: g / accum_steps, gsum)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt
        )
        return TrainState(new_params, new_opt), {
            "loss": lsum / accum_steps,
            **opt_metrics,
        }

    return train_step
