"""AdamW in pure JAX with sharding-aware state.

Optimizer state mirrors the parameter tree, so parameter PartitionSpecs
apply verbatim to ``m``/``v`` (first/second moments) — FSDP-sharded
params get FSDP-sharded optimizer state for free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    m: Any  # like params (f32)
    v: Any  # like params (f32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_adamw(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros)


def abstract_adamw(params: Any) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params
    )
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), zeros, zeros)


def adamw_state_specs(param_specs: Any):
    """Optimizer-state PartitionSpecs from parameter specs."""
    from jax.sharding import PartitionSpec as P

    return AdamWState(P(), param_specs, param_specs)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to ``min_lr_ratio``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: AdamWState,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping and decoupled decay."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        AdamWState(step, new_m, new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
