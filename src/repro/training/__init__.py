from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, MarkovTextStream, batch_for
from repro.training.grpo import group_advantages, grpo_loss, make_grpo_step
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.training.train_step import TrainState, init_train_state, make_train_step

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "DataConfig",
    "MarkovTextStream",
    "TrainState",
    "adamw_update",
    "batch_for",
    "group_advantages",
    "grpo_loss",
    "init_adamw",
    "init_train_state",
    "load_checkpoint",
    "make_grpo_step",
    "make_train_step",
    "save_checkpoint",
]
