"""Deterministic synthetic data pipeline.

Offline container => no real corpora.  The stream is a seeded sparse
Markov chain over the vocabulary with local n-gram structure, so models
*can* learn it (loss drops well below ln(V)) and runs are reproducible.
Sharding-friendly: batches are produced as numpy and device_put with the
batch sharding by the caller/launcher.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 4  # successors per state -> entropy ~= ln(branching)
    # tokens are drawn from the first ``active_vocab`` ids (None = all):
    # keeps the transition table memorizable at example scale while the
    # model's embedding/unembedding still span the full vocab
    active_vocab: int | None = None


class MarkovTextStream:
    """Infinite iterator of {tokens: [B, S+1]} next-token batches."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, K = cfg.active_vocab or cfg.vocab_size, cfg.branching
        self._active = V
        # sparse transition table: each token has K allowed successors
        self._succ = rng.integers(0, V, size=(V, K), dtype=np.int64)
        self._rng = np.random.default_rng(cfg.seed + 1)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B, S, K = cfg.batch_size, cfg.seq_len, cfg.branching
        V = self._active
        toks = np.empty((B, S + 1), dtype=np.int32)
        toks[:, 0] = self._rng.integers(0, V, size=B)
        choices = self._rng.integers(0, K, size=(B, S))
        for t in range(S):
            toks[:, t + 1] = self._succ[toks[:, t], choices[:, t]]
        return {"tokens": toks}

    def entropy_floor(self) -> float:
        """Best achievable mean NLL (uniform over K successors)."""
        return float(np.log(self.cfg.branching))


def batch_for(cfg_model, shape, seed: int = 0) -> Dict[str, np.ndarray]:
    """One concrete (non-abstract) batch matching an assigned InputShape."""
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    if cfg_model.family == "audio":
        return {
            "frames": rng.standard_normal((B, S, cfg_model.d_model)).astype(np.float32)
            * 0.02,
            "tokens": rng.integers(
                0, cfg_model.vocab_size, size=(B, cfg_model.decoder_seq)
            ).astype(np.int32),
        }
    if cfg_model.family == "vlm":
        P = cfg_model.num_patches
        return {
            "tokens": rng.integers(0, cfg_model.vocab_size, size=(B, S - P)).astype(
                np.int32
            ),
            "patch_embeds": rng.standard_normal((B, P, cfg_model.d_model)).astype(
                np.float32
            )
            * 0.02,
        }
    return {
        "tokens": rng.integers(0, cfg_model.vocab_size, size=(B, S)).astype(np.int32)
    }
