"""Checkpointing: flat-path .npz snapshots of arbitrary pytrees.

No orbax in the offline image; numpy archives are portable, atomic
(write-then-rename) and sufficient for CPU-scale runs.  Sharded arrays
are gathered before save (callers on real pods would swap in a
process-local variant writing one shard file per host).
"""

from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    flat = _flatten(tree)
    flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for cand in (tmp, tmp + ".npz"):
            if os.path.exists(cand):
                os.remove(cand)


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype respected)."""
    data = np.load(path)
    step = int(data["__step__"]) if "__step__" in data else 0
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_t, leaf in leaves_with_path:
        key = "/".join(_path_str(p) for p in path_t)
        if key not in data:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != expected {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
