"""Decoder-only transformer covering the dense / moe / ssm / hybrid / vlm
families, as pure functions over schema-driven parameter trees.

Depth is handled with ``jax.lax.scan`` over layer-stacked parameters
(small HLO, fast CPU compiles, remat-friendly); training wraps the layer
body in ``jax.checkpoint``.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDef,
    attention_schema,
    cross_entropy,
    decode_attention,
    embed_schema,
    ffn_schema,
    lm_head_schema,
    logits_fn,
    multihead_attention,
    rms_norm,
    stacked,
)
from repro.sharding.rules import Rules

AUX_LB_COEF = 0.01
AUX_Z_COEF = 0.001


# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------


def layer_schema(cfg: ModelConfig) -> Dict[str, Any]:
    """Schema of ONE layer (unstacked)."""
    d = cfg.d_model
    norm = lambda: ParamDef((d,), (None,), init="ones")
    s: Dict[str, Any] = {}
    if cfg.family in ("dense", "vlm", "moe", "hybrid"):
        s["attn"] = attention_schema(cfg)
        s["norm_attn"] = norm()
    if cfg.family in ("ssm", "hybrid"):
        s["ssm"] = ssm_mod.ssm_schema(cfg)
        s["norm_ssm"] = norm()
    if cfg.family == "hybrid":
        # per-branch output norms, Hymba-style parallel-head fusion
        s["norm_attn_out"] = norm()
        s["norm_ssm_out"] = norm()
    if cfg.family == "moe":
        s["moe"] = moe_mod.moe_schema(cfg)
        s["norm_ffn"] = norm()
    elif cfg.family in ("dense", "vlm", "hybrid"):
        s["ffn"] = ffn_schema(cfg)
        s["norm_ffn"] = norm()
    return s


def model_schema(cfg: ModelConfig) -> Dict[str, Any]:
    one = layer_schema(cfg)
    s: Dict[str, Any] = {
        "embed": embed_schema(cfg),
        "layers": jax.tree.map(
            lambda p: stacked(p, cfg.num_layers),
            one,
            is_leaf=lambda x: isinstance(x, ParamDef),
        ),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = lm_head_schema(cfg)
    return s


# ---------------------------------------------------------------------------
# layer body (full-sequence)
# ---------------------------------------------------------------------------


def layer_forward(
    lp: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules],
    sliding_window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    aux = {"load_balance": jnp.zeros((), jnp.float32), "router_z": jnp.zeros((), jnp.float32)}
    if cfg.family == "ssm":
        x = x + ssm_mod.ssd_scan(lp["ssm"], rms_norm(x, lp["norm_ssm"], cfg.norm_eps), cfg, rules)
        return x, aux
    if cfg.family == "hybrid":
        h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
        a = multihead_attention(
            lp["attn"], h, positions, cfg, rules=rules, sliding_window=sliding_window
        )
        s = ssm_mod.ssd_scan(lp["ssm"], rms_norm(x, lp["norm_ssm"], cfg.norm_eps), cfg, rules)
        fused = 0.5 * (
            rms_norm(a, lp["norm_attn_out"], cfg.norm_eps)
            + rms_norm(s, lp["norm_ssm_out"], cfg.norm_eps)
        )
        x = x + fused
        x = x + _ffn(lp, x, cfg, rules)
        return x, aux
    # dense / vlm / moe
    h = rms_norm(x, lp["norm_attn"], cfg.norm_eps)
    x = x + multihead_attention(
        lp["attn"], h, positions, cfg, rules=rules, sliding_window=sliding_window
    )
    if cfg.family == "moe":
        y, moe_aux = moe_mod.moe_ffn(
            lp["moe"], rms_norm(x, lp["norm_ffn"], cfg.norm_eps), cfg, rules
        )
        x = x + y
        aux = moe_aux
    else:
        x = x + _ffn(lp, x, cfg, rules)
    return x, aux


def _ffn(lp: dict, x: jax.Array, cfg: ModelConfig, rules: Optional[Rules]) -> jax.Array:
    from repro.models.layers import swiglu_ffn

    return swiglu_ffn(lp["ffn"], rms_norm(x, lp["norm_ffn"], cfg.norm_eps), rules)


# ---------------------------------------------------------------------------
# full forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def forward(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules],
    sliding_window: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Trunk over embedded inputs x [B,S,D] -> (hidden, aux)."""

    def body(carry, lp):
        h, lb, zl = carry
        h, aux = layer_forward(lp, h, positions, cfg, rules, sliding_window)
        return (h, lb + aux["load_balance"], zl + aux["router_z"]), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    (x, lb, zl), _ = jax.lax.scan(
        body,
        (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        params["layers"],
        unroll=cfg.scan_unroll,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    denom = max(cfg.num_layers, 1)
    return x, {"load_balance": lb / denom, "router_z": zl / denom}


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return params["embed"][tokens].astype(jnp.dtype(cfg.dtype))


def lm_loss(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token LM loss for dense/moe/ssm/hybrid (+ vlm with patches)."""
    tokens = batch["tokens"]  # [B, S_text]
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    prefix = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype)  # [B, P, D]
        prefix = patches.shape[1]
        x = jnp.concatenate([patches, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))
    h, aux = forward(params, x, positions, cfg, rules)
    # predict text tokens; positions prefix..S-2 predict tokens 1..
    h_txt = h[:, prefix:, :]
    logits = logits_fn(params, h_txt[:, :-1, :], cfg)
    if rules is not None:
        logits = rules.constrain(logits, ("batch", None, "vocab"))
    loss = cross_entropy(logits, tokens[:, 1:])
    total = loss + AUX_LB_COEF * aux["load_balance"] + AUX_Z_COEF * aux["router_z"]
    return total, {"lm_loss": loss, **aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    k_cache: Optional[jax.Array]  # FLAT [L, B, S_max, KV*hd] (see layers.decode_attention)
    v_cache: Optional[jax.Array]
    ssm_state: Optional[jax.Array]  # [L, B, H, hd, N]
    pos: jax.Array  # scalar int32: next position to write


def init_decode_state(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    dtype=jnp.bfloat16,
) -> DecodeState:
    L = cfg.num_layers
    kc = vc = st = None
    if cfg.family in ("dense", "vlm", "moe", "hybrid", "audio"):
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kc = jnp.zeros((L, batch, cache_len, kv * hd), dtype)
        vc = jnp.zeros((L, batch, cache_len, kv * hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        st = jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return DecodeState(kc, vc, st, jnp.zeros((), jnp.int32))


def decode_state_specs(cfg: ModelConfig, rules: Rules, batch: int, cache_len: int):
    """PartitionSpecs matching init_decode_state's tree."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    kc_spec = vc_spec = st_spec = None
    if cfg.family in ("dense", "vlm", "moe", "hybrid", "audio"):
        # flat kv*hd trailing dim; the SEQUENCE dim shards on the model
        # axis (batch on data) — head-dim sharding would force per-token
        # cache re-gathers for GQA (see rules.py 'cache_seq')
        if batch >= rules.data_extent and batch % rules.data_extent == 0:
            dims = ("layers", "batch", "cache_seq", None)
        else:  # long-context single-sequence: shard the cache on sequence
            dims = ("layers", None, "kv_seq", "qkv")
        kc_spec = rules.spec((L, batch, cache_len, kv * hd), dims)
        vc_spec = kc_spec
    if cfg.family in ("ssm", "hybrid"):
        st_spec = rules.spec(
            (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("layers", "batch", "ssm_inner", None, None),
        )
    from jax.sharding import PartitionSpec as P

    return DecodeState(kc_spec, vc_spec, st_spec, P())


def decode_step(
    params: dict,
    state: DecodeState,
    token: jax.Array,  # [B, 1] int32
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
    sliding_window: int = 0,
) -> Tuple[jax.Array, DecodeState]:
    """One decode step: returns (logits [B, V], new state)."""
    B = token.shape[0]
    x = embed_tokens(params, token, cfg)  # [B,1,D]
    pos = state.pos

    def body(h, inputs):
        lp, kc, vc, st = inputs
        new_kc, new_vc, new_st = kc, vc, st
        if cfg.family == "ssm":
            y, new_st = ssm_mod.ssd_decode_step(
                lp["ssm"], rms_norm(h, lp["norm_ssm"], cfg.norm_eps), st, cfg
            )
            h = h + y
            return h, (new_kc, new_vc, new_st)
        if cfg.family == "hybrid":
            hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
            a, new_kc, new_vc = decode_attention(
                lp["attn"], hn, pos, kc, vc, cfg, sliding_window=sliding_window
            )
            s, new_st = ssm_mod.ssd_decode_step(
                lp["ssm"], rms_norm(h, lp["norm_ssm"], cfg.norm_eps), st, cfg
            )
            fused = 0.5 * (
                rms_norm(a, lp["norm_attn_out"], cfg.norm_eps)
                + rms_norm(s, lp["norm_ssm_out"], cfg.norm_eps)
            )
            h = h + fused
            h = h + _ffn(lp, h, cfg, rules)
            return h, (new_kc, new_vc, new_st)
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        a, new_kc, new_vc = decode_attention(
            lp["attn"], hn, pos, kc, vc, cfg, sliding_window=sliding_window
        )
        h = h + a
        if cfg.family == "moe":
            y, _ = moe_mod.moe_ffn(
                lp["moe"], rms_norm(h, lp["norm_ffn"], cfg.norm_eps), cfg, rules
            )
            h = h + y
        else:
            h = h + _ffn(lp, h, cfg, rules)
        return h, (new_kc, new_vc, new_st)

    dummy = jnp.zeros((cfg.num_layers, 0), jnp.float32)
    xs = (
        params["layers"],
        state.k_cache if state.k_cache is not None else dummy,
        state.v_cache if state.v_cache is not None else dummy,
        state.ssm_state if state.ssm_state is not None else dummy,
    )
    h, (kc, vc, st) = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h, cfg)[:, 0, :]
    new_state = DecodeState(
        kc if state.k_cache is not None else None,
        vc if state.v_cache is not None else None,
        st if state.ssm_state is not None else None,
        pos + 1,
    )
    return logits, new_state


def prefill(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, DecodeState]:
    """Prefill: full forward producing last-token logits + decode caches.

    Uses a per-layer pass that also emits this layer's K/V for the cache
    (attention archs) or the final SSD state (ssm/hybrid).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    if cfg.family in ("ssm", "hybrid"):
        return _prefill_with_state(params, x, positions, cfg, rules)

    def body(h, lp):
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        from repro.models.layers import apply_rope

        k = (hn @ lp["attn"]["wk"]).reshape(B, S, kv, hd)
        v = hn @ lp["attn"]["wv"]  # flat [B, S, kv*hd]
        kc = apply_rope(k, positions, cfg.rope_theta).reshape(B, S, kv * hd)
        h, _ = layer_forward(lp, h, positions, cfg, rules)
        return h, (kc, v)

    h, (kcs, vcs) = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h[:, -1:, :], cfg)[:, 0, :]
    state = DecodeState(
        kcs.astype(jnp.dtype(cfg.dtype)),
        vcs.astype(jnp.dtype(cfg.dtype)),
        None,
        jnp.array(S, jnp.int32),
    )
    return logits, state


def _prefill_with_state(params, x, positions, cfg, rules):
    """Prefill for ssm/hybrid: emit per-layer final SSD state (+KV)."""
    B, S, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(h, lp):
        kc = vc = jnp.zeros((0,), jnp.float32)
        if cfg.family == "hybrid":
            hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
            from repro.models.layers import apply_rope

            k = (hn @ lp["attn"]["wk"]).reshape(B, S, kv, hd)
            v = hn @ lp["attn"]["wv"]  # flat [B, S, kv*hd]
            kc = apply_rope(k, positions, cfg.rope_theta).reshape(B, S, kv * hd)
            vc = v
        ssm_in = rms_norm(h, lp["norm_ssm"], cfg.norm_eps)
        y_ssm, st = ssm_mod.ssd_scan_with_state(lp["ssm"], ssm_in, cfg, rules)
        if cfg.family == "ssm":
            h = h + y_ssm
        else:
            a = multihead_attention(lp["attn"], rms_norm(h, lp["norm_attn"], cfg.norm_eps),
                                    positions, cfg, rules=rules)
            fused = 0.5 * (
                rms_norm(a, lp["norm_attn_out"], cfg.norm_eps)
                + rms_norm(y_ssm, lp["norm_ssm_out"], cfg.norm_eps)
            )
            h = h + fused
            h = h + _ffn(lp, h, cfg, rules)
        return h, (kc, vc, st)

    h, (kcs, vcs, sts) = jax.lax.scan(body, x, params["layers"], unroll=cfg.scan_unroll)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h[:, -1:, :], cfg)[:, 0, :]
    state = DecodeState(
        kcs.astype(jnp.dtype(cfg.dtype)) if cfg.family == "hybrid" else None,
        vcs.astype(jnp.dtype(cfg.dtype)) if cfg.family == "hybrid" else None,
        sts,
        jnp.array(S, jnp.int32),
    )
    return logits, state
