from repro.models.model import ModelApi, build_model

__all__ = ["ModelApi", "build_model"]
