"""Mixture-of-Experts FFN with sort-based static-capacity dispatch.

Design (TPU-native, DESIGN.md §5):

* token-choice top-k routing with a static per-expert capacity
  ``C = ceil(T * k / E * capacity_factor)`` (rounded up to a multiple of
  128 for MXU alignment) — static shapes keep the step jit-compatible;
* dispatch via **argsort by expert id** + rank-within-expert scatter into
  an ``[E, C, D]`` buffer (no ``[T, E, C]`` one-hot blow-up, which would
  be ~20 TB for the kimi-k2 train shape);
* expert FFNs run as one batched einsum over the expert dim;
* sharding: the buffer is constrained to ``P('model' on E, data on C)``,
  so GSPMD emits the expert-parallel all-to-all between token shards and
  expert shards — the same communication pattern as a hand-written EP
  dispatch;
* auxiliary losses: switch-style load-balance + router z-loss.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef
from repro.sharding.rules import Rules


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.expert_d_ff
    return {
        "router": ParamDef((d, e), ("embed", None)),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamDef((e, f, d), ("expert", "mlp", "embed")),
    }


def expert_capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    if c >= 128:
        return ((c + 127) // 128) * 128  # MXU-aligned
    # serve-path (decode) capacities are tiny; a hard 128 floor inflated
    # the kimi-k2 decode dispatch buffer 16x (EXPERIMENTS.md §Perf).
    # Sublane-aligned (8) is enough when the tile is this small.
    return max(8, ((c + 7) // 8) * 8)


def moe_ffn(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, D] -> (y, aux_losses).

    Two dispatch paths:

    * ``_moe_ffn_global`` — single global argsort + scatter.  Correct
      everywhere, but under GSPMD the scatter's computed indices span the
      whole token space, so the partitioner **replicates** the [E*C, D]
      buffer per device and stitches it with giant all-reduces (measured:
      64 GB f32 buffers + 103 GB all-reduces per layer on the granite
      train_4k shape — EXPERIMENTS.md §Perf iteration 1).  Kept as the
      reference path for unsharded/test meshes.
    * ``_moe_ffn_sharded`` — dispatch and combine run *locally per data
      shard* inside :func:`jax.shard_map` (each shard scatters into its
      own capacity block of a [E, G*C_loc, D] buffer), then the expert
      einsums stay in GSPMD land: constraining the buffer to
      ``('expert','capacity')`` emits the expert-parallel all-to-all when
      E divides the model axis (kimi-k2), and falls back to TP on the
      FFN dim otherwise (granite's E=40).  This is the TPU-native
      adaptation: local VMEM-sized scatters, MXU-aligned capacity.
    """
    B, S, D = x.shape
    if rules is not None:
        G = rules.data_extent
        if G > 1 and B % G == 0:
            return _moe_ffn_sharded(params, x, cfg, rules)
    return _moe_ffn_global(params, x, cfg, rules)


def _moe_ffn_global(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = expert_capacity(T, cfg)
    xt = x.reshape(T, D)

    # ---- routing -------------------------------------------------------
    logits = (xt @ params["router"]).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # [T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # ---- dispatch: sort (token, slot) pairs by expert ------------------
    flat_e = top_e.reshape(T * K)  # expert of each assignment
    flat_p = top_p.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e)  # stable -> FCFS within expert
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_p = flat_p[order]
    # rank of each assignment within its expert
    counts = jnp.bincount(flat_e, length=E)  # [E]

    # ---- aux losses (bincount-based: no [T,K,E] one-hot blow-up) --------
    density = counts.astype(jnp.float32) / T  # routed fraction per expert
    router_mean = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(density * router_mean) / K
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    rank = jnp.arange(T * K) - starts[sorted_e]
    keep = rank < C  # capacity-dropped assignments contribute nothing
    slot = jnp.where(keep, sorted_e * C + rank, E * C)  # OOB -> dropped

    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[slot].add(
        jnp.where(keep[:, None], xt[sorted_tok], 0).astype(x.dtype),
        mode="drop",
    )
    buf = buf.reshape(E, C, D)
    if rules is not None:
        buf = rules.constrain(buf, ("expert", "capacity", None))

    # ---- expert FFNs (batched over E) -----------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    if rules is not None:
        h = rules.constrain(h, ("expert", "capacity", "mlp"))
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if rules is not None:
        out = rules.constrain(out, ("expert", "capacity", None))

    # ---- combine: gather back and weight by router prob ----------------
    out_flat = out.reshape(E * C, D)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.minimum(slot, E * C - 1)], 0
    )  # [T*K, D] in sorted order
    contrib = gathered * sorted_p[:, None].astype(x.dtype)
    y_flat = jnp.zeros((T, D), x.dtype).at[sorted_tok].add(contrib)
    y = y_flat.reshape(B, S, D)
    if rules is not None:
        y = rules.constrain(y, ("batch", None, None))
    return y, {"load_balance": lb_loss, "router_z": z_loss}


# ---------------------------------------------------------------------------
# shard_map dispatch (TPU-native path; EXPERIMENTS.md §Perf iteration 1)
# ---------------------------------------------------------------------------


def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` moved out of ``jax.experimental`` (and renamed
    ``check_rep`` -> ``check_vma``) across jax releases; dispatch to
    whichever spelling this jax provides."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def _local_dispatch(xt, router, E, K, C_loc, E_buf=None, e_lo=None, n_slice=None):
    """Per-shard dispatch: xt [T_loc, D] -> buffer + combine metadata.

    Pure dense ops on local data — no cross-shard indices, so GSPMD never
    sees a global scatter.  ``E_buf >= E`` pads the buffer's expert dim
    (EP divisibility); tokens only ever route to the first E experts.
    ``[e_lo, e_lo + n_slice)`` restricts the built buffer to one expert
    slice (the caller's model rank); ``n_slice`` must be a static int
    (``e_lo`` may be a traced ``axis_index``).  Metadata keeps global
    expert coordinates.
    """
    E_buf = E if E_buf is None else E_buf
    if e_lo is None:
        e_lo, n_slice = 0, E_buf
    T_loc = xt.shape[0]
    logits = (xt @ router).astype(jnp.float32)  # [T_loc, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(T_loc * K)
    flat_p = top_p.reshape(T_loc * K)
    flat_tok = jnp.repeat(jnp.arange(T_loc), K)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    sorted_p = flat_p[order]
    counts = jnp.bincount(flat_e, length=E)

    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T_loc * K) - starts[sorted_e]
    keep = rank < C_loc
    # global slot coordinates (combine metadata)
    slot = jnp.where(keep, sorted_e * C_loc + rank, E_buf * C_loc)

    # this rank's expert slice only; out-of-slice assignments drop
    local = keep & (sorted_e >= e_lo) & (sorted_e < e_lo + n_slice)
    local_slot = jnp.where(
        local, (sorted_e - e_lo) * C_loc + rank, n_slice * C_loc
    )

    # slots are unique per (expert, rank), so a plain scatter-set suffices
    # — scatter-ADD on bf16 is what the CPU backend upcasts to f32, which
    # would double every boundary collective (§Perf iteration 4)
    buf = jnp.zeros((n_slice * C_loc, xt.shape[1]), xt.dtype)
    buf = buf.at[local_slot].set(
        jnp.where(local[:, None], xt[sorted_tok], 0).astype(xt.dtype),
        mode="drop",
    )
    # inverse sort permutation lets the combine run scatter-free
    inv = jnp.argsort(order)
    meta = (inv, sorted_p.astype(xt.dtype), slot, keep)
    aux = (counts, jnp.mean(probs, axis=0),
           jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))))
    return buf.reshape(n_slice, C_loc, xt.shape[1]), meta, aux


def _local_combine(out, inv, sorted_p, slot, keep, T_loc, slot_lo=0):
    """Per-shard combine: expert-slice output [E_l, C_loc, D] -> partial
    y [T_loc, D] (zeros for assignments outside this slice).

    Scatter-free: gather each assignment's expert output in sorted order,
    undo the sort with ``inv``, and sum the K contributions per token
    with a dense reshape — no scatter-add (CPU upcasts those to f32, and
    TPUs much prefer dense reductions).
    """
    E_l, C_loc, D = out.shape
    K = inv.shape[0] // T_loc
    n = E_l * C_loc
    out_flat = out.reshape(n, D)
    idx = slot - slot_lo
    mine = keep & (idx >= 0) & (idx < n)
    gathered = jnp.where(mine[:, None], out_flat[jnp.clip(idx, 0, n - 1)], 0)
    contrib = gathered * sorted_p[:, None].astype(out.dtype)
    return contrib[inv].reshape(T_loc, K, D).sum(axis=1, dtype=out.dtype)


def _moe_ffn_sharded(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: Rules,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    G = rules.data_extent
    T_loc = T // G
    C_loc = expert_capacity(T_loc, cfg)
    data = rules.data_axes
    data_ax = data if len(data) > 1 else data[0]

    # BEYOND-PAPER (EXPERIMENTS.md §Perf iteration 2): when E does not
    # divide the model axis (granite: 40 on 16) the expert dim cannot
    # shard, and the fallback TP-on-F contraction all-reduces the [E, C,
    # D] activations every layer (measured 710 GB/device per step).  Pad
    # the *dispatch buffer and weights* — never the router — to the next
    # multiple of the model axis: dead experts receive no tokens and no
    # gradient, and EP's all-to-alls replace the all-reduces.
    e_axes = rules.mapping.get("expert", ())
    e_extent = math.prod(rules.axis_sizes[a] for a in e_axes) if e_axes else 1
    E_pad = E if E % e_extent == 0 else ((E + e_extent - 1) // e_extent) * e_extent

    x = rules.constrain(x, ("batch", None, None))

    # BEYOND-PAPER (EXPERIMENTS.md §Perf iteration 5): the dispatch and
    # combine shard_maps run over the data AND model axes.  Each model
    # rank builds only its own expert slice of the buffer (routing is
    # recomputed per rank — a trivial [T_loc, E] matmul), so the dispatch
    # output is *born* EP-sharded: no replicated boundary, hence no
    # [E_pad, C_loc, D]-sized cotangent psum in the backward.  The
    # combine likewise reduces each rank's expert-slice contribution and
    # psums only the [T_loc, D] result — 24x less boundary traffic than
    # gathering full-E expert outputs per data shard.
    e_ax = (e_axes if len(e_axes) > 1 else e_axes[0]) if e_axes else None
    E_l = E_pad // e_extent

    def dispatch(xs, router):
        # xs: [B/G, S, D] local block; build only this rank's expert slice
        if e_ax is not None:
            m = jax.lax.axis_index(e_ax)
        else:
            m = 0
        buf, (inv, p, slot, keep), (counts, rmean, z) = _local_dispatch(
            xs.reshape(-1, D), router, E, K, C_loc,
            E_buf=E_pad, e_lo=m * E_l, n_slice=E_l,
        )
        # lead shard axes of extent 1 so out_specs can map them
        return (
            buf[None, None],  # [1, 1, E_l, C_loc, D] -> [G, M, E_pad/M...]
            inv[None],
            p[None],
            slot[None],
            keep[None],
            counts[None],
            rmean[None],
            z[None],
        )

    buf, inv, p, slot, keep, counts, rmean, z = _shard_map(
        dispatch,
        mesh=rules.mesh,
        in_specs=(P(data_ax, None, None), P(None, None)),
        out_specs=(
            P(data_ax, e_ax, None, None, None),  # [G, M, E_l, C_loc, D]
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax),
        ),
        check_vma=False,
    )(x, params["router"])
    buf = buf.reshape(G, E_pad, C_loc, D)  # model-sharded dim stays in place

    # ---- aux losses from per-shard partials ------------------------------
    density = jnp.sum(counts, axis=0).astype(jnp.float32) / T
    router_mean = jnp.mean(rmean, axis=0)
    lb_loss = E * jnp.sum(density * router_mean) / K
    z_loss = jnp.mean(z)

    # ---- expert FFNs under GSPMD -----------------------------------------
    # The buffer keeps its [G, E_pad, C_loc, D] layout and only its
    # SHARDING changes: (data on G) -> (data on G, model on E).  A
    # dim-preserving respec is the pattern GSPMD lowers to a true
    # all-to-all; reshaping [G, E, C, D] -> [E, G*C, D] across the
    # sharded dims instead lowered to full all-gathers (measured 534
    # GB/device — EXPERIMENTS.md §Perf iteration 3).  With E padded to
    # the model-axis extent EP always engages.
    def _pad_e(w):
        if E_pad == E:
            return w
        return jnp.pad(w, ((0, E_pad - E),) + ((0, 0),) * (w.ndim - 1))

    buf = rules.constrain(buf, ("capacity", "expert", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, _pad_e(params["w_gate"])))
    h = h * jnp.einsum("gecd,edf->gecf", buf, _pad_e(params["w_up"]))
    h = rules.constrain(h, ("capacity", "expert", None, "mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, _pad_e(params["w_down"]))
    out = rules.constrain(out, ("capacity", "expert", None, None))

    # combine over BOTH axes: each model rank reduces its expert slice's
    # contribution and psums only the [T_loc, D] result (iteration 5)
    def combine(out_s, inv_s, p_s, slot_s, keep_s):
        if e_ax is not None:
            m = jax.lax.axis_index(e_ax)
        else:
            m = 0
        y = _local_combine(
            out_s[0, 0], inv_s[0], p_s[0], slot_s[0], keep_s[0], T_loc,
            slot_lo=m * E_l * C_loc,
        )
        if e_ax is not None:
            y = jax.lax.psum(y, e_ax)
        return y.reshape(1, B // G, S, D)

    y = _shard_map(
        combine,
        mesh=rules.mesh,
        in_specs=(
            P(data_ax, e_ax, None, None, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
            P(data_ax, None),
        ),
        out_specs=P(data_ax, None, None, None),
        check_vma=False,
    )(out.reshape(G, e_extent, E_l, C_loc, D), inv, p, slot, keep)
    y = y.reshape(B, S, D)
    y = rules.constrain(y, ("batch", None, None))
    return y, {"load_balance": lb_loss, "router_z": z_loss}
