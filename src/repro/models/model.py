"""Unified model API: ``build_model(cfg)`` -> :class:`ModelApi`.

One façade across the six architecture families; everything downstream
(training, serving, dry-run, RL driver) goes through this interface.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import (
    ParamDef,
    abstract_from_schema,
    init_from_schema,
    specs_from_schema,
)
from repro.sharding.rules import Rules


@dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    schema: Dict[str, Any]

    # ---- params ----------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return init_from_schema(key, self.schema, jnp.dtype(self.cfg.dtype))

    def abstract_params(self) -> dict:
        return abstract_from_schema(self.schema, jnp.dtype(self.cfg.dtype))

    def param_specs(self, rules: Rules) -> dict:
        return specs_from_schema(self.schema, rules)

    def param_count(self) -> int:
        leaves = jax.tree.leaves(
            self.schema, is_leaf=lambda x: isinstance(x, ParamDef)
        )
        total = 0
        for p in leaves:
            n = 1
            for s in p.shape:
                n *= s
            total += n
        return total

    def active_param_count(self) -> int:
        """6*N*D roofline uses *active* params for MoE (top-k of experts)."""
        cfg = self.cfg
        total = self.param_count()
        if cfg.family != "moe" or not cfg.num_experts:
            return total
        per_expert = 3 * cfg.d_model * cfg.expert_d_ff * cfg.num_layers
        inactive = per_expert * (cfg.num_experts - cfg.experts_per_token)
        return total - inactive

    # ---- training --------------------------------------------------------
    def loss_fn(
        self, params: dict, batch: Dict[str, jax.Array], rules: Optional[Rules] = None
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        if self.cfg.family == "audio":
            return encdec.lm_loss(params, batch, self.cfg, rules)
        return transformer.lm_loss(params, batch, self.cfg, rules)

    # ---- serving ---------------------------------------------------------
    def prefill(self, params, batch, rules=None):
        if self.cfg.family == "audio":
            return encdec.prefill(params, batch, self.cfg, rules)
        return transformer.prefill(params, batch, self.cfg, rules)

    def decode_step(self, params, state, token, rules=None, sliding_window: int = 0):
        if self.cfg.family == "audio":
            return encdec.decode_step(
                params, state, token, self.cfg, rules, sliding_window
            )
        return transformer.decode_step(
            params, state, token, self.cfg, rules, sliding_window
        )

    def init_decode_state(self, batch: int, cache_len: int):
        dt = jnp.dtype(self.cfg.dtype)
        if self.cfg.family == "audio":
            return encdec.init_decode_state(self.cfg, batch, cache_len, dt)
        return transformer.init_decode_state(self.cfg, batch, cache_len, dt)

    def abstract_decode_state(self, batch: int, cache_len: int):
        return jax.eval_shape(lambda: self.init_decode_state(batch, cache_len))

    def decode_state_specs(self, rules: Rules, batch: int, cache_len: int):
        if self.cfg.family == "audio":
            return encdec.decode_state_specs(self.cfg, rules, batch, cache_len)
        return transformer.decode_state_specs(self.cfg, rules, batch, cache_len)


def build_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "audio":
        schema = encdec.model_schema(cfg)
    else:
        schema = transformer.model_schema(cfg)
    return ModelApi(cfg=cfg, schema=schema)
