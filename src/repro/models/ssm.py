"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill uses the **chunked SSD algorithm**: the sequence is cut
into chunks of length ``Q``; within a chunk the recurrence is expanded
into a (masked, decay-weighted) quadratic form that maps onto the MXU;
across chunks a short ``lax.scan`` carries the [H, hd, N] state.  Decode
is the O(1) recurrence — the reason SSM archs run ``long_500k`` natively.

Shapes follow the Mamba-2 conventions:
  d_inner = expand * d_model, H = d_inner / head_dim, N = ssm_state.
Per head h: state S[hd, N];  y_t = C_t . S_t + D x_t,
  S_t = exp(dt_t A_h) S_{t-1} + dt_t * (x_t outer B_t).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDef, rms_norm
from repro.sharding.rules import Rules


def ssm_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in_z": ParamDef((d, di), ("embed", "ssm_inner")),
        "w_in_x": ParamDef((d, di), ("embed", "ssm_inner")),
        "w_in_b": ParamDef((d, n), ("embed", None)),
        "w_in_c": ParamDef((d, n), ("embed", None)),
        "w_in_dt": ParamDef((d, h), ("embed", None)),
        "a_log": ParamDef((h,), (None,), init="zeros"),
        "dt_bias": ParamDef((h,), (None,), init="zeros"),
        "d_skip": ParamDef((h,), (None,), init="ones"),
        "out_norm": ParamDef((di,), (None,), init="ones"),
        "w_out": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _project(params: dict, x: jax.Array, cfg: ModelConfig):
    """x: [B,S,D] -> z,xs: [B,S,H,hd]; b,c: [B,S,N]; dt: [B,S,H]."""
    B, S, _ = x.shape
    H, hd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = (x @ params["w_in_z"]).reshape(B, S, H, hd)
    xs = (x @ params["w_in_x"]).reshape(B, S, H, hd)
    b = x @ params["w_in_b"]  # [B,S,N] (shared across heads, Mamba-2 default)
    c = x @ params["w_in_c"]
    dt = jax.nn.softplus(
        (x @ params["w_in_dt"]).astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # [B,S,H]
    return z, xs, b, c, dt


def ssd_scan(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> jax.Array:
    """Full-sequence SSD mixer: x [B,S,D] -> [B,S,D].  S % chunk == 0."""
    y, _ = ssd_scan_with_state(params, x, cfg, rules)
    return y


def ssd_scan_with_state(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, jax.Array]:
    """SSD mixer returning (y, final_state [B,H,hd,N]) for prefill."""
    B, S, D = x.shape
    H, hd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    NC = S // Q

    z, xs, b, c, dt = _project(params, x, cfg)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # [H], negative
    dA = dt * A  # [B,S,H] log-decay per step

    # chunked views
    xs_c = xs.reshape(B, NC, Q, H, hd)
    b_c = b.reshape(B, NC, Q, N).astype(jnp.float32)
    c_c = c.reshape(B, NC, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, NC, Q, H)
    dA_c = dA.reshape(B, NC, Q, H)
    cum = jnp.cumsum(dA_c, axis=2)  # [B,NC,Q,H] inclusive within-chunk

    # ---- intra-chunk (quadratic, attention-like) ------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,NC,Q,Q,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Lmask = jnp.where(tri, jnp.exp(diff), 0.0)  # [B,NC,Q,Q,H]
    cb = jnp.einsum("bnim,bnjm->bnij", c_c, b_c)  # [B,NC,Q,Q]
    w = cb[..., None] * Lmask  # [B,NC,Q,Q,H]
    xdt = xs_c * dt_c[..., None].astype(xs.dtype)  # dt-weighted inputs
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", w.astype(xs.dtype), xdt)

    # ---- chunk states + inter-chunk scan --------------------------------
    # state contribution of chunk: sum_j exp(cum_last - cum_j) * B_j ⊗ xdt_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,NC,Q,H]
    state_chunk = jnp.einsum(
        "bnjm,bnjh,bnjhd->bnhdm",
        b_c,
        decay_to_end.astype(jnp.float32),
        xdt.astype(jnp.float32),
    )  # [B,NC,H,hd,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :]).astype(jnp.float32)  # [B,NC,H]

    def step(carry, inputs):
        s_prev = carry  # [B,H,hd,N]
        s_new, g = inputs  # [B,H,hd,N], [B,H]
        s = s_prev * g[:, :, None, None] + s_new
        return s, s_prev  # emit the state *entering* the chunk

    s0 = jnp.zeros((B, H, hd, N), jnp.float32)
    final_state, entering = jax.lax.scan(
        step,
        s0,
        (
            jnp.moveaxis(state_chunk, 1, 0),  # [NC,B,H,hd,N]
            jnp.moveaxis(chunk_decay, 1, 0),  # [NC,B,H]
        ),
    )
    entering = jnp.moveaxis(entering, 0, 1)  # [B,NC,H,hd,N]

    # inter-chunk output: C_i . (decay_from_start_i * S_entering)
    decay_from_start = jnp.exp(cum).astype(jnp.float32)  # [B,NC,Q,H]
    y_inter = jnp.einsum(
        "bnim,bnhdm,bnih->bnihd", c_c, entering, decay_from_start
    ).astype(xs.dtype)

    y = (y_intra + y_inter).reshape(B, S, H, hd)
    y = y + xs * params["d_skip"].astype(xs.dtype)[None, None, :, None]
    # gated output norm + projection
    y = y * jax.nn.silu(z)
    y = y.reshape(B, S, H * hd)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    if rules is not None:
        y = rules.constrain(y, ("batch", None, "ssm_inner"))
    return y @ params["w_out"], final_state


def ssm_decode_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), dtype)


def ssd_decode_step(
    params: dict,
    x: jax.Array,  # [B,1,D]
    state: jax.Array,  # [B,H,hd,N] f32
    cfg: ModelConfig,
) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrent decode step.  Returns (y [B,1,D], new_state)."""
    B = x.shape[0]
    H, hd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, b, c, dt = _project(params, x, cfg)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    g = jnp.exp(dt[:, 0] * A)  # [B,H]
    xdt = (xs[:, 0] * dt[:, 0, :, None].astype(xs.dtype)).astype(jnp.float32)  # [B,H,hd]
    outer = jnp.einsum("bhd,bm->bhdm", xdt, b[:, 0].astype(jnp.float32))
    new_state = state * g[:, :, None, None] + outer
    y = jnp.einsum("bhdm,bm->bhd", new_state, c[:, 0].astype(jnp.float32)).astype(xs.dtype)
    y = y + xs[:, 0] * params["d_skip"].astype(xs.dtype)[None, :, None]
    y = y * jax.nn.silu(z[:, 0])
    y = y.reshape(B, 1, H * hd)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    return y @ params["w_out"], new_state
