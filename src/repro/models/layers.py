"""Shared model building blocks: params DSL, RMSNorm, RoPE, GQA attention.

All modules are pure functions over explicit parameter pytrees.  Parameter
trees are described by :class:`ParamDef` schemas — one schema drives both
initialization (values) and sharding (PartitionSpecs via logical dims),
so the two can never drift apart.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.rules import Rules


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]  # logical axis labels, len == len(shape)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self) -> None:
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


Schema = Dict[str, "SchemaNode"]  # nested dicts of ParamDef


def init_from_schema(key: jax.Array, schema, dtype) -> dict:
    flat, treedef = jax.tree.flatten(schema, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    vals = []
    for k, pdef in zip(keys, flat):
        if pdef.init == "zeros":
            vals.append(jnp.zeros(pdef.shape, dtype))
        elif pdef.init == "ones":
            vals.append(jnp.ones(pdef.shape, dtype))
        else:
            vals.append(
                (jax.random.normal(k, pdef.shape, jnp.float32) * pdef.scale).astype(dtype)
            )
    return jax.tree.unflatten(treedef, vals)


def abstract_from_schema(schema, dtype) -> dict:
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
        schema,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def specs_from_schema(schema, rules: Rules) -> dict:
    return jax.tree.map(
        lambda p: rules.spec(p.shape, p.dims),
        schema,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def stacked(pdef: ParamDef, layers: int) -> ParamDef:
    """Layer-stacked parameter for ``lax.scan`` over the depth dimension."""
    return ParamDef(
        (layers, *pdef.shape), ("layers", *pdef.dims), pdef.init, pdef.scale
    )


# ---------------------------------------------------------------------------
# norms / positional encodings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * weight


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, N, head_dim]; positions: [B, S] (int32)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal / sliding-window / cross / decode)
# ---------------------------------------------------------------------------


def attention_schema(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamDef((d, h * hd), ("embed", "qkv")),
        "wk": ParamDef((d, kv * hd), ("embed", "qkv")),
        "wv": ParamDef((d, kv * hd), ("embed", "qkv")),
        "wo": ParamDef((h * hd, d), ("qkv", "embed")),
    }


def multihead_attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    mask: Optional[jax.Array] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
    sliding_window: int = 0,
    rules: Optional[Rules] = None,
    use_rope: bool = True,
) -> jax.Array:
    """Full (non-incremental) GQA attention.

    ``kv_override`` supplies external keys/values (cross-attention);
    ``sliding_window > 0`` restricts attention to the last W positions.
    """
    B, S, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kv

    # BEYOND-PAPER (EXPERIMENTS.md §Perf, pair 1 iteration 6): when the
    # head count does not divide the model axis (granite 24H, smollm 15H,
    # hymba 25H on 16) GSPMD replicates ALL attention activations and
    # compute.  Pad (kv, g) group-interleaved — real head i keeps its kv
    # group, dead q columns are zero, dead kv rows have zero keys so
    # their scores are uniform over zero values, and zero out-proj rows
    # cancel dead-head outputs — so the math is exactly GQA(h, kv) while
    # the padded head dim shards.
    wq, wk, wv, wo = params["wq"], params["wk"], params["wv"], params["wo"]
    kv_p, g_p = kv, g
    if rules is not None and kv_override is None:
        ext = rules.extent("heads")
        if ext > 1 and h % ext:
            kv_p, g_p = _pad_plan(kv, g, ext)
        if kv_p != kv or g_p != g:
            D = wq.shape[0]
            wq = jnp.pad(
                wq.reshape(D, kv, g, hd),
                ((0, 0), (0, kv_p - kv), (0, g_p - g), (0, 0)),
            ).reshape(D, kv_p * g_p * hd)
            wk = jnp.pad(
                wk.reshape(D, kv, hd), ((0, 0), (0, kv_p - kv), (0, 0))
            ).reshape(D, kv_p * hd)
            wv = jnp.pad(
                wv.reshape(D, kv, hd), ((0, 0), (0, kv_p - kv), (0, 0))
            ).reshape(D, kv_p * hd)
            wo = jnp.pad(
                wo.reshape(kv, g, hd, D),
                ((0, kv_p - kv), (0, g_p - g), (0, 0), (0, 0)),
            ).reshape(kv_p * g_p * hd, D)
    h_p = kv_p * g_p

    q = (x @ wq).reshape(B, S, h_p, hd)
    if kv_override is None:
        k = (x @ wk).reshape(B, S, kv_p, hd)
        v = (x @ wv).reshape(B, S, kv_p, hd)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
    if rules is not None:
        q = rules.constrain(q, ("batch", None, "heads", None))
        k = rules.constrain(k, ("batch", None, "kv_heads", None))
        v = rules.constrain(v, ("batch", None, "kv_heads", None))
    Sk = k.shape[1]
    qg = q.reshape(B, S, kv_p, g_p, hd)
    if S >= 2 * Q_CHUNK and mask is None:
        # long sequences: query-block scan keeps the live score tile at
        # [B, KV, G, Q_CHUNK, Sk] instead of [.., S, Sk] (memory roofline)
        out = _chunked_attention(qg, k, v, positions, causal, sliding_window, hd)
    else:
        scores = jnp.einsum(
            "bqngd,bknd->bngqk", qg, k, preferred_element_type=jnp.float32
        )
        scores = scores / math.sqrt(hd)
        if causal:
            qpos = positions[:, :, None]  # [B,Sq,1]
            kpos = jnp.arange(Sk)[None, None, :]
            causal_mask = kpos <= qpos
            if sliding_window > 0:
                causal_mask &= kpos > qpos - sliding_window
            scores = jnp.where(causal_mask[:, None, None, :, :], scores, -1e30)
        if mask is not None:
            scores = jnp.where(mask, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bngqk,bknd->bqngd", w, v)
    out = out.reshape(B, S, h_p * hd)
    return out @ wo


def _pad_plan(kv: int, g: int, ext: int) -> Tuple[int, int]:
    """Smallest (kv_p >= kv, g_p >= g) with kv_p*g_p % ext == 0."""
    best = None
    for kv_p in range(kv, kv + ext):
        for g_p in range(g, g + ext):
            if (kv_p * g_p) % ext == 0:
                cand = (kv_p * g_p, kv_p, g_p)
                if best is None or cand < best:
                    best = cand
    assert best is not None
    return best[1], best[2]


Q_CHUNK = 1024


def _chunked_attention(
    qg: jax.Array,  # [B, S, KV, G, hd]
    k: jax.Array,  # [B, Sk, KV, hd]
    v: jax.Array,
    positions: jax.Array,  # [B, S]
    causal: bool,
    sliding_window: int,
    hd: int,
) -> jax.Array:
    """Flash-style query-block scan (online softmax over full K per block)."""
    B, S, kv, g, _ = qg.shape
    Sk = k.shape[1]
    nq = S // Q_CHUNK
    assert S % Q_CHUNK == 0
    q_blocks = qg.reshape(B, nq, Q_CHUNK, kv, g, hd)
    pos_blocks = positions.reshape(B, nq, Q_CHUNK)
    kpos = jnp.arange(Sk)[None, None, :]

    def block(carry, inp):
        qb, pb = inp  # [B,Q,KV,G,hd], [B,Q]
        scores = jnp.einsum(
            "bqngd,bknd->bngqk", qb, k, preferred_element_type=jnp.float32
        ) / math.sqrt(hd)
        if causal:
            m = kpos <= pb[:, :, None]
            if sliding_window > 0:
                m &= kpos > pb[:, :, None] - sliding_window
            scores = jnp.where(m[:, None, None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(qb.dtype)
        ob = jnp.einsum("bngqk,bknd->bqngd", w, v)
        return carry, ob

    _, out_blocks = jax.lax.scan(
        block, None, (jnp.moveaxis(q_blocks, 1, 0), jnp.moveaxis(pos_blocks, 1, 0))
    )
    return jnp.moveaxis(out_blocks, 0, 1).reshape(B, S, kv, g, hd)


def decode_attention(
    params: dict,
    x: jax.Array,
    pos: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cfg: ModelConfig,
    *,
    sliding_window: int = 0,
    update_cache: bool = True,
    use_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a FLAT [B, S_max, KV*hd] cache.

    The cache keeps its head/dim axes merged so the joint ``kv*hd`` dim
    can shard across the whole model axis even when ``kv`` alone cannot
    (llama3's kv=8 on a 16-way axis: GSPMD splits the 16 ways as
    kv:8 x hd:2 after the in-kernel reshape).  Storing the cache
    [B, S, kv, hd] with kv unshardable forced GSPMD to re-gather the
    ENTIRE cache every decoded token (measured 2x34 GB/step on
    llama3-8b decode_32k — EXPERIMENTS.md §Perf iteration 6).

    ``pos`` is the scalar current position (same for the whole batch).
    Returns (output [B,1,D], new_k_cache, new_v_cache).
    """
    B = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    g = h // kv
    S_max = k_cache.shape[1]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = (x @ params["wq"]).reshape(B, 1, h, hd)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
    if update_cache:
        k_new = (x @ params["wk"]).reshape(B, 1, kv, hd)
        v_new = (x @ params["wv"]).reshape(B, 1, kv, hd)
        if use_rope:
            k_new = apply_rope(k_new, positions, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.reshape(B, 1, kv * hd).astype(k_cache.dtype), (0, pos, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.reshape(B, 1, kv * hd).astype(v_cache.dtype), (0, pos, 0)
        )

    if sliding_window > 0 and sliding_window < S_max:
        # sub-quadratic long-context decode: attend to the last W entries
        start = jnp.clip(pos - sliding_window + 1, 0, S_max - sliding_window)
        k_att = jax.lax.dynamic_slice(
            k_cache, (0, start, 0), (B, sliding_window, kv * hd)
        ).reshape(B, sliding_window, kv, hd)
        v_att = jax.lax.dynamic_slice(
            v_cache, (0, start, 0), (B, sliding_window, kv * hd)
        ).reshape(B, sliding_window, kv, hd)
        kpos = start + jnp.arange(sliding_window)
    else:
        k_att = k_cache.reshape(B, S_max, kv, hd)
        v_att = v_cache.reshape(B, S_max, kv, hd)
        kpos = jnp.arange(S_max)
    qg = q.reshape(B, 1, kv, g, hd)
    scores = jnp.einsum("bqngd,bknd->bngqk", qg, k_att, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    valid = (kpos <= pos)[None, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngqk,bknd->bqngd", w, v_att).reshape(B, 1, h * hd)
    return out @ params["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_schema(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "mlp")),
        "w_up": ParamDef((d, f), ("embed", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "embed")),
    }


def swiglu_ffn(params: dict, x: jax.Array, rules: Optional[Rules] = None) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    if rules is not None:
        h = rules.constrain(h, ("batch", None, "mlp"))
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------


def embed_schema(cfg: ModelConfig) -> ParamDef:
    # vocab-sharded ONLY: FSDP-sharding the D axis too makes the token
    # gather un-partitionable (SPMD "involuntary full rematerialization",
    # ~30 GB/device of extra all-reduce on kimi-k2 — measured, see
    # EXPERIMENTS.md §Perf).
    return ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", None), scale=0.02)


def lm_head_schema(cfg: ModelConfig) -> ParamDef:
    return ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))


def logits_fn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"], preferred_element_type=jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"], preferred_element_type=jnp.float32)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE; logits [B,S,V] (f32), labels [B,S]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
