"""Whisper-style encoder-decoder backbone (audio family).

The mel-spectrogram + conv feature extractor is STUBBED per the brief:
inputs are precomputed frame embeddings ``[B, S_enc, D]``.  The encoder
is a non-causal transformer; the decoder adds cross-attention to the
encoder output.  Decode = one token against a self-attention cache of
``seq_len`` plus a fixed-length cross-attention cache.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    ParamDef,
    apply_rope,
    attention_schema,
    cross_entropy,
    decode_attention,
    embed_schema,
    ffn_schema,
    lm_head_schema,
    logits_fn,
    multihead_attention,
    rms_norm,
    stacked,
    swiglu_ffn,
)
from repro.sharding.rules import Rules


def _norm(cfg: ModelConfig) -> ParamDef:
    return ParamDef((cfg.d_model,), (None,), init="ones")


def encoder_layer_schema(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "attn": attention_schema(cfg),
        "norm_attn": _norm(cfg),
        "ffn": ffn_schema(cfg),
        "norm_ffn": _norm(cfg),
    }


def decoder_layer_schema(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "self_attn": attention_schema(cfg),
        "norm_self": _norm(cfg),
        "cross_attn": attention_schema(cfg),
        "norm_cross": _norm(cfg),
        "ffn": ffn_schema(cfg),
        "norm_ffn": _norm(cfg),
    }


def model_schema(cfg: ModelConfig) -> Dict[str, Any]:
    st = lambda sch, L: jax.tree.map(
        lambda p: stacked(p, L), sch, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    s: Dict[str, Any] = {
        "embed": embed_schema(cfg),
        "enc_layers": st(encoder_layer_schema(cfg), cfg.encoder_layers),
        "enc_norm": _norm(cfg),
        "dec_layers": st(decoder_layer_schema(cfg), cfg.num_layers),
        "final_norm": _norm(cfg),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = lm_head_schema(cfg)
    return s


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(
    params: dict, frames: jax.Array, cfg: ModelConfig, rules: Optional[Rules] = None
) -> jax.Array:
    """frames: [B, S_enc, D] (stub embeddings) -> encoder hidden."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = frames.astype(jnp.dtype(cfg.dtype))
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def body(h, lp):
        hn = rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        h = h + multihead_attention(
            lp["attn"], hn, positions, cfg, causal=False, rules=rules
        )
        h = h + swiglu_ffn(lp["ffn"], rms_norm(h, lp["norm_ffn"], cfg.norm_eps), rules)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder (training / teacher-forced)
# ---------------------------------------------------------------------------


def decode_train(
    params: dict,
    tokens: jax.Array,
    enc_out: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> jax.Array:
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Se = enc_out.shape[1]

    def body(h, lp):
        hn = rms_norm(h, lp["norm_self"], cfg.norm_eps)
        h = h + multihead_attention(lp["self_attn"], hn, positions, cfg, rules=rules)
        hn = rms_norm(h, lp["norm_cross"], cfg.norm_eps)
        ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, Se, kv, hd)
        cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, Se, kv, hd)
        h = h + multihead_attention(
            lp["cross_attn"], hn, positions, cfg,
            kv_override=(ck, cv), causal=False, use_rope=False, rules=rules,
        )
        h = h + swiglu_ffn(lp["ffn"], rms_norm(h, lp["norm_ffn"], cfg.norm_eps), rules)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def lm_loss(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc_out = encode(params, batch["frames"], cfg, rules)
    h = decode_train(params, batch["tokens"], enc_out, cfg, rules)
    logits = logits_fn(params, h[:, :-1, :], cfg)
    if rules is not None:
        logits = rules.constrain(logits, ("batch", None, "vocab"))
    loss = cross_entropy(logits, batch["tokens"][:, 1:])
    return loss, {"lm_loss": loss}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


class EncDecState(NamedTuple):
    self_k: jax.Array  # FLAT [L, B, S_max, KV*hd] (see layers.decode_attention)
    self_v: jax.Array
    cross_k: jax.Array  # FLAT [L, B, S_enc, KV*hd]
    cross_v: jax.Array
    pos: jax.Array


def init_decode_state(
    cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16
) -> EncDecState:
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    Se = cfg.encoder_seq
    return EncDecState(
        jnp.zeros((L, batch, cache_len, kv * hd), dtype),
        jnp.zeros((L, batch, cache_len, kv * hd), dtype),
        jnp.zeros((L, batch, Se, kv * hd), dtype),
        jnp.zeros((L, batch, Se, kv * hd), dtype),
        jnp.zeros((), jnp.int32),
    )


def decode_state_specs(cfg: ModelConfig, rules: Rules, batch: int, cache_len: int):
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    if batch >= rules.data_extent and batch % rules.data_extent == 0:
        dims = ("layers", "batch", "cache_seq", None)
    else:
        dims = ("layers", None, "kv_seq", "qkv")
    self_spec = rules.spec((L, batch, cache_len, kv * hd), dims)
    cross_spec = rules.spec(
        (L, batch, cfg.encoder_seq, kv * hd), ("layers", "batch", "cache_seq", None)
    )
    from jax.sharding import PartitionSpec as P

    return EncDecState(self_spec, self_spec, cross_spec, cross_spec, P())


def decode_step(
    params: dict,
    state: EncDecState,
    token: jax.Array,
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
    sliding_window: int = 0,
) -> Tuple[jax.Array, EncDecState]:
    B = token.shape[0]
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    pos = state.pos
    h_kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(h, inputs):
        lp, sk, sv, ck, cv = inputs
        hn = rms_norm(h, lp["norm_self"], cfg.norm_eps)
        a, sk, sv = decode_attention(
            lp["self_attn"], hn, pos, sk, sv, cfg, sliding_window=sliding_window
        )
        h = h + a
        hn = rms_norm(h, lp["norm_cross"], cfg.norm_eps)
        a, _, _ = decode_attention(
            lp["cross_attn"], hn, jnp.array(cfg.encoder_seq - 1, jnp.int32),
            ck, cv, cfg, update_cache=False, use_rope=False,
        )
        h = h + a
        h = h + swiglu_ffn(lp["ffn"], rms_norm(h, lp["norm_ffn"], cfg.norm_eps), rules)
        return h, (sk, sv)

    h, (sk, sv) = jax.lax.scan(
        body,
        x,
        (params["dec_layers"], state.self_k, state.self_v, state.cross_k, state.cross_v),
        unroll=cfg.scan_unroll,
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h, cfg)[:, 0, :]
    return logits, EncDecState(sk, sv, state.cross_k, state.cross_v, pos + 1)


def prefill(
    params: dict,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    rules: Optional[Rules] = None,
) -> Tuple[jax.Array, EncDecState]:
    """Encode audio frames; build cross caches; teacher-force the prompt."""
    frames = batch["frames"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, frames, cfg, rules)
    Se = enc_out.shape[1]
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))

    def body(h, lp):
        hn = rms_norm(h, lp["norm_self"], cfg.norm_eps)
        sk = (hn @ lp["self_attn"]["wk"]).reshape(B, S, kv, hd)
        sv = (hn @ lp["self_attn"]["wv"]).reshape(B, S, kv, hd)
        sk = apply_rope(sk, positions, cfg.rope_theta)
        h = h + multihead_attention(lp["self_attn"], hn, positions, cfg, rules=rules)
        hn = rms_norm(h, lp["norm_cross"], cfg.norm_eps)
        ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(B, Se, kv, hd)
        cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(B, Se, kv, hd)
        h = h + multihead_attention(
            lp["cross_attn"], hn, positions, cfg,
            kv_override=(ck, cv), causal=False, use_rope=False, rules=rules,
        )
        h = h + swiglu_ffn(lp["ffn"], rms_norm(h, lp["norm_ffn"], cfg.norm_eps), rules)
        # caches stored FLAT [B, S, kv*hd] (see layers.decode_attention)
        return h, (
            sk.reshape(B, S, kv * hd),
            sv.reshape(B, S, kv * hd),
            ck.reshape(B, Se, kv * hd),
            cv.reshape(B, Se, kv * hd),
        )

    h, (sks, svs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, h[:, -1:, :], cfg)[:, 0, :]
    dt = jnp.dtype(cfg.dtype)
    return logits, EncDecState(
        sks.astype(dt), svs.astype(dt), cks.astype(dt), cvs.astype(dt),
        jnp.array(S, jnp.int32),
    )
