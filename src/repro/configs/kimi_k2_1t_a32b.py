"""kimi-k2-1t-a32b [arXiv:2501.kimi2] — trillion-param MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,
        expert_d_ff=2048,
        num_experts=384,
        experts_per_token=8,
        vocab_size=163840,
        capacity_factor=1.0,  # trillion-scale: tight capacity keeps the
        # dispatch buffer within HBM (EXPERIMENTS.md §Perf discusses this)
        rope_theta=50_000.0,
        source="arXiv:2501.kimi2",
    )
)
