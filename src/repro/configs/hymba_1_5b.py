"""hymba-1.5b [arXiv:2411.13676] — hybrid: parallel attention + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=2,
        rope_theta=10_000.0,
        source="arXiv:2411.13676",
    )
)
