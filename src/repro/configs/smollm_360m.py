"""smollm-360m [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        rope_theta=10_000.0,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )
)
