"""internvl2-1b [arXiv:2404.16821] — InternViT + InternLM2(Qwen2-0.5B) backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The ViT frontend
is a STUB per the brief: ``input_specs`` provides precomputed patch
embeddings (256 patches) of the right shape.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        num_patches=256,
        rope_theta=1_000_000.0,
        source="arXiv:2404.16821",
    )
)
