from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    all_configs,
    get_config,
    register,
)

__all__ = [
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "all_configs",
    "get_config",
    "register",
]
