"""Model configuration schema + the assigned input-shape registry.

Every assigned architecture provides a ``src/repro/configs/<id>.py`` with
the exact published config (source cited in brackets) plus a reduced
smoke variant (2 layers, d_model <= 512, <= 4 experts) used by CPU tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    expert_d_ff: int = 0  # per-expert FFN width (MoE archs)
    capacity_factor: float = 1.25
    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- audio (enc-dec) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500  # cross-attention KV length at decode
    decoder_seq: int = 448  # text positions in train batches
    # --- vlm ---
    num_patches: int = 0  # stub vision-prefix length
    # --- common ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 8192  # used only by the long-decode variant
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1  # full-unroll used by the cost-calibration pass
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            expert_d_ff=min(self.expert_d_ff, 128) if self.expert_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            decoder_seq=16 if self.encoder_layers else self.decoder_seq,
            num_patches=8 if self.num_patches else 0,
            sliding_window=64,
            # drop-free at smoke scale: cap(T) = 2T covers the max
            # per-expert load, so full-sequence forward == incremental
            # decode even with sub-128 (8-aligned) capacities
            capacity_factor=4.0,
            dtype="float32",
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    # importing the module registers its config
    from repro.configs import (  # noqa: F401
        glm4_9b,
        granite_moe_3b_a800m,
        hymba_1_5b,
        internvl2_1b,
        kimi_k2_1t_a32b,
        llama3_8b,
        llama3_2_1b,
        mamba2_130m,
        smollm_360m,
        whisper_medium,
    )
