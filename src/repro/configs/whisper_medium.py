"""whisper-medium [arXiv:2212.04356] — enc-dec; conv/mel frontend STUBBED.

24L (decoder) + 24L (encoder) d_model=1024 16H (kv=16, MHA) d_ff=4096
vocab=51865.  ``input_specs`` provides precomputed frame embeddings.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=24,
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        encoder_seq=1500,
        decoder_seq=448,
        rope_theta=10_000.0,  # we use RoPE in place of learned abs. pos.
        source="arXiv:2212.04356",
    )
)
