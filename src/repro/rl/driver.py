"""Wiring helpers: build ARL-Tangram or baseline stacks for a workload,
run steps, and the live GRPO-with-Tangram loop used by the e2e example.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import (
    ServerlessLlmSystem,
    StaticGpuServiceSystem,
    TrajectoryStaticCpuSystem,
    UnmanagedApiSystem,
)
from repro.core.cluster import ClusterSpec, paper_testbed
from repro.core.fairqueue import FairSharePolicy
from repro.core.managers.basic import BasicResourceManager
from repro.core.managers.cpu import CpuManager
from repro.core.managers.gpu import GpuManager, ServiceSpec
from repro.core.orchestrator import Orchestrator, SchedulingPolicy
from repro.core.simulator import EventLoop
from repro.core.tangram import Tangram
from repro.rl.rollout import RolloutRunner, StepStats
from repro.rl.tasks import TrajectorySpec, workload_services


def build_managers(
    cluster: ClusterSpec,
    services: Sequence[str] = (),
    service_state_gb: float = 40.0,
    loop: Optional[EventLoop] = None,
) -> Tuple[Dict[str, object], EventLoop]:
    loop = loop or EventLoop()
    managers: Dict[str, object] = {}
    if cluster.cpu_nodes:
        managers["cpu"] = CpuManager(cluster.cpu_nodes)
    if cluster.gpu_nodes:
        managers["gpu"] = GpuManager(
            cluster.gpu_nodes,
            [ServiceSpec(s, service_state_gb) for s in services],
        )
    for api in cluster.apis:
        managers[api.name] = BasicResourceManager(api, loop.clock)
    return managers, loop


def build_orchestrator(
    cluster: ClusterSpec,
    policy: Optional[SchedulingPolicy] = None,
    services: Sequence[str] = (),
    service_state_gb: float = 40.0,
    loop: Optional[EventLoop] = None,
    incremental: bool = True,
    fair_share: Optional[FairSharePolicy] = None,
    shards: Optional[int] = None,
) -> Orchestrator:
    """One orchestrator, swappable policy (ElasticScheduler by default,
    or the FCFS/static baseline policies for ablations).  ``fair_share``
    turns on multi-tenant weighted queueing across task_ids; ``shards``
    switches the round loop to the plan/commit engine (repro.core.shards)
    with that many parallel planners."""
    managers, loop = build_managers(cluster, services, service_state_gb, loop)
    return Orchestrator(
        managers, loop=loop, policy=policy, incremental=incremental,
        fair_share=fair_share, shards=shards,
    )


def build_tangram(
    cluster: ClusterSpec,
    services: Sequence[str] = (),
    service_state_gb: float = 40.0,
    loop: Optional[EventLoop] = None,
    depth: int = 2,
    fair_share: Optional[FairSharePolicy] = None,
) -> Tangram:
    from repro.core.scheduler import ElasticScheduler

    managers, loop = build_managers(cluster, services, service_state_gb, loop)
    tg = Tangram(managers, loop=loop, fair_share=fair_share)
    tg.scheduler = ElasticScheduler(depth=depth, history=tg.history)
    return tg


def run_tangram_step(
    trajectories: Sequence[TrajectorySpec],
    cluster: Optional[ClusterSpec] = None,
    depth: int = 2,
) -> Tuple[StepStats, Tangram]:
    cluster = cluster or paper_testbed()
    services = workload_services(trajectories)
    tg = build_tangram(cluster, services, depth=depth)
    runner = RolloutRunner({"*": tg, "cpu": tg, "gpu": tg,
                            **{a.name: tg for a in cluster.apis}}, tg.loop)
    stats = runner.run_step(trajectories)
    return stats, tg


def run_baseline_step(
    trajectories: Sequence[TrajectorySpec],
    cluster: Optional[ClusterSpec] = None,
    gpu_baseline: str = "static",  # "static" | "serverless"
) -> Tuple[StepStats, Dict[str, object]]:
    """Workload-specific baselines (§6.1): k8s pods for CPU, SGLang-style
    static services (or ServerlessLLM) for GPU, unmanaged API calls."""
    cluster = cluster or paper_testbed()
    loop = EventLoop()
    services = workload_services(trajectories)
    systems: Dict[str, object] = {}
    cpu_sys = TrajectoryStaticCpuSystem(total_cores=cluster.total_cores, loop=loop)
    systems["cpu"] = cpu_sys
    if services:
        if gpu_baseline == "static":
            per = max(1, cluster.total_devices // 4 // max(1, len(services)))
            gpu_sys = StaticGpuServiceSystem({s: per for s in services}, tp=4, loop=loop)
        else:
            gpu_sys = ServerlessLlmSystem(
                cluster.total_devices, {s: 40.0 for s in services}, loop=loop
            )
        systems["gpu"] = gpu_sys
    api_sys = UnmanagedApiSystem(rate_limit=64, loop=loop)
    for api in cluster.apis:
        systems[api.name] = api_sys
    systems["*"] = cpu_sys
    runner = RolloutRunner(systems, loop)
    stats = runner.run_step(trajectories)
    return stats, systems


# ---------------------------------------------------------------------------
# Live end-to-end: GRPO training with rewards through ARL-Tangram
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LiveStepReport:
    grpo_loss: float
    mean_reward: float
    mean_act: float
    rollout_wall_s: float
    update_wall_s: float


class LiveGrpoDriver:
    """Trains a small policy with GRPO; reward computation executes REAL
    JAX inference while resource occupancy/latency is accounted through
    ARL-Tangram's scheduler (measured durations feed the DES)."""

    def __init__(self, policy_cfg, judge_cfg, group_size: int = 4, seed: int = 0):
        import jax
        import jax.numpy as jnp

        from repro.models import build_model
        from repro.serving.engine import Engine, GenerationConfig
        from repro.serving.reward_service import deploy_reward_service
        from repro.training import AdamWConfig, init_train_state, make_grpo_step

        self.jax, self.jnp = jax, jnp
        self.api = build_model(policy_cfg)
        self.state = init_train_state(self.api, jax.random.PRNGKey(seed))
        self.group_size = group_size
        self.gen_cfg = GenerationConfig(max_new_tokens=16, temperature=1.0, cache_len=64)
        self.judge = deploy_reward_service("judge", judge_cfg)
        self.grpo_step = jax.jit(make_grpo_step(self.api, AdamWConfig(lr=1e-3,
                                                                      warmup_steps=2,
                                                                      total_steps=100)))
        self._key = jax.random.PRNGKey(seed + 1)

    def _engine(self):
        from repro.serving.engine import Engine

        return Engine(self.api, self.state.params, self.gen_cfg)

    def run_step(self, prompts: np.ndarray, tangram: Tangram) -> LiveStepReport:
        """prompts: [B, S0] int32.  One rollout + reward + GRPO update."""
        jnp = self.jnp
        t0 = time.perf_counter()
        B, S0 = prompts.shape
        G = self.group_size
        engine = self._engine()
        # group rollouts: repeat each prompt G times
        rep = np.repeat(prompts, G, axis=0)
        self._key, sub = self.jax.random.split(self._key)
        gen_toks, gen_logps = engine.generate({"tokens": jnp.asarray(rep)}, key=sub)
        seqs = np.concatenate([rep, np.asarray(gen_toks)], axis=1)
        rollout_s = time.perf_counter() - t0

        # rewards through Tangram: real judge scoring, measured duration
        rewards = np.zeros(B * G, np.float32)

        def score_fn(idx):
            def run(dop: int) -> float:
                t = time.perf_counter()
                s = float(self.judge.score(jnp.asarray(seqs[idx : idx + 1]))[0])
                rewards[idx] = s
                return time.perf_counter() - t

            return run

        from repro.core.action import Action, ResourceRequest
        from repro.rl.tasks import GPU_ELASTICITY

        futs = []
        for i in range(B * G):
            a = Action(
                name="reward:judge",
                cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
                key_resource="gpu",
                elasticity=GPU_ELASTICITY,
                base_duration=0.05,
                duration_sampler=score_fn(i),
                service="judge",
                task_id="live",
                trajectory_id=f"live-{i}",
            )
            futs.append(tangram.submit(a))
        tangram.run()
        mean_act = tangram.telemetry.mean_act()

        # GRPO update (real)
        from repro.training import group_advantages
        from repro.training.grpo import token_logprobs

        adv = group_advantages(jnp.asarray(rewards.reshape(B, G))).reshape(-1)
        tokens = jnp.asarray(seqs)
        old_logp = token_logprobs(self.state.params, tokens, self.api)
        mask = np.zeros((B * G, seqs.shape[1] - 1), np.float32)
        mask[:, S0 - 1 :] = 1.0  # only generated positions train
        batch = {
            "tokens": tokens,
            "mask": jnp.asarray(mask),
            "advantages": adv,
            "old_logp": old_logp,
            "ref_logp": old_logp,
        }
        t1 = time.perf_counter()
        self.state, metrics = self.grpo_step(self.state, batch)
        update_s = time.perf_counter() - t1
        return LiveStepReport(
            grpo_loss=float(metrics["loss"]),
            mean_reward=float(rewards.mean()),
            mean_act=mean_act,
            rollout_wall_s=rollout_s,
            update_wall_s=update_s,
        )
