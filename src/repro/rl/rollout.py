"""Rollout runner: drives trajectory state machines through any external
resource system (ARL-Tangram or a baseline) inside the DES.

Per the paper's workflow (§2.1 Fig. 2): each trajectory interleaves LLM
generation (time advance, training-cluster side) with external actions
(submitted to the system under test, critical-path blocking); rewards
run at trajectory end; the RL *step* completes when every trajectory in
the batch has its reward (synchronous GRPO step).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from repro.core.simulator import EventLoop
from repro.rl.tasks import TrajectorySpec


@dataclasses.dataclass
class StepStats:
    step_duration: float
    mean_act: float
    p99_act: float
    failure_rate: float
    breakdown: Dict[str, float]
    stage_durations: Dict[str, float]  # total time per stage label
    retries: int = 0  # lifecycle re-queues observed across all systems


class RolloutRunner:
    """Runs one synchronous RL step (a batch of trajectories)."""

    def __init__(
        self,
        systems: Dict[str, object],  # resource-kind -> system; "*" = default
        loop: EventLoop,
    ) -> None:
        self.systems = systems
        self.loop = loop
        self._remaining = 0
        self._t_begin = math.inf
        self._t_end = 0.0
        self._stage_time: Dict[str, float] = {"gen": 0.0, "tool": 0.0, "reward": 0.0}

    def _system_for(self, action) -> object:
        for rtype in action.cost:
            if rtype in self.systems:
                return self.systems[rtype]
        return self.systems["*"]

    # ------------------------------------------------------------------
    def run_step(self, trajectories: Sequence[TrajectorySpec]) -> StepStats:
        self._remaining = len(trajectories)
        self._t_begin = math.inf
        self._t_end = 0.0
        for spec in trajectories:
            self.loop.call_after(spec.arrival_s, lambda s=spec: self._start_traj(s))
        self.loop.run()
        # aggregate telemetry from every distinct system
        seen = {id(s): s for s in self.systems.values()}
        acts: List[float] = []
        fails = 0
        total = 0
        retries = 0
        sums = {"exec": 0.0, "queue": 0.0, "overhead": 0.0}
        for sys_ in seen.values():
            tel = sys_.telemetry
            for r in tel.records:
                total += 1
                retries += r.retries
                if r.failed:
                    fails += 1
                else:
                    acts.append(r.act)
                    sums["exec"] += r.exec_dur
                    sums["queue"] += r.queue_dur
                    sums["overhead"] += r.sys_overhead
        # per-action means, so the breakdown decomposes mean_act exactly
        # (a per-system mean-of-means would not when several baseline
        # systems with different record counts coexist)
        breakdown = {
            k: (v / len(acts) if acts else math.nan) for k, v in sums.items()
        }
        acts.sort()
        return StepStats(
            step_duration=self._t_end - min(self._t_begin, self._t_end),
            mean_act=sum(acts) / len(acts) if acts else math.nan,
            p99_act=acts[int(0.99 * (len(acts) - 1))] if acts else math.nan,
            failure_rate=fails / total if total else 0.0,
            breakdown=breakdown,
            stage_durations=dict(self._stage_time),
            retries=retries,
        )

    # ------------------------------------------------------------------
    def _start_traj(self, spec: TrajectorySpec) -> None:
        self._t_begin = min(self._t_begin, self.loop.clock.now())
        for sys_ in {id(s): s for s in self.systems.values()}.values():
            sys_.trajectory_start(spec.traj_id, {"traj_mem_gb": spec.memory_gb})
        self._next_turn(spec, 0)

    def _next_turn(self, spec: TrajectorySpec, turn_idx: int) -> None:
        if turn_idx >= len(spec.turns):
            self._run_rewards(spec)
            return
        turn = spec.turns[turn_idx]
        self._stage_time["gen"] += turn.gen_s

        def after_gen() -> None:
            if not turn.actions:
                self._next_turn(spec, turn_idx + 1)
                return
            pending = len(turn.actions)
            t_submit = self.loop.clock.now()

            def one_done(_fut) -> None:
                nonlocal pending
                pending -= 1
                self._stage_time["tool"] += self.loop.clock.now() - t_submit
                if pending == 0:
                    self._next_turn(spec, turn_idx + 1)

            for tmpl in turn.actions:
                action = tmpl.make(spec.task_id, spec.traj_id)
                fut = self._system_for(action).submit(action)
                fut.add_done_callback(one_done)

        self.loop.call_after(turn.gen_s, after_gen)

    def _run_rewards(self, spec: TrajectorySpec) -> None:
        if not spec.reward:
            self._finish_traj(spec)
            return
        pending = len(spec.reward)
        t_submit = self.loop.clock.now()

        def one_done(_fut) -> None:
            nonlocal pending
            pending -= 1
            self._stage_time["reward"] += self.loop.clock.now() - t_submit
            if pending == 0:
                self._finish_traj(spec)

        for tmpl in spec.reward:
            action = tmpl.make(spec.task_id, spec.traj_id)
            fut = self._system_for(action).submit(action)
            fut.add_done_callback(one_done)

    def _finish_traj(self, spec: TrajectorySpec) -> None:
        for sys_ in {id(s): s for s in self.systems.values()}.values():
            sys_.trajectory_end(spec.traj_id)
        self._t_end = max(self._t_end, self.loop.clock.now())
        self._remaining -= 1
