"""Agentic RL workload generators (paper §6.1), trace-parameterized.

Three production workloads drive the evaluation:

* **AI coding** — per-trajectory isolated environments; multi-turn
  ReAct with short shell/edit tool calls (~ms-seconds, 1 CPU,
  non-scalable) and a *long-tailed, CPU-scalable* reward action (test
  execution, pytest -n parallelizable, DoP 1..32).  Generators are
  calibrated so the env-busy ratio matches the paper's ~47% (Fig. 3c).
* **DeepSearch** — BrowseComp-style: rate-limited API calls
  (search / fetch / pdf; non-scalable; Basic manager) plus an LLM-judge
  reward on the GPU pool (scalable DoP 1-8).
* **MOPD** — multi-teacher distillation: trajectory log-probs computed
  against ~10 teacher-model services; invocations concentrate at
  trajectory boundaries (the 3-orders-of-magnitude burstiness of
  Fig. 3d).

Durations are sampled from seeded lognormals; every action carries the
paper's §4.1 formulation (vectorized cost, key elasticity resource,
profiled elasticity for scalable kinds).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, List, Sequence

from repro.core.action import (
    Action,
    AmdahlElasticity,
    TableElasticity,
    fixed,
    ResourceRequest,
)

GPU_ELASTICITY = TableElasticity(table=((1, 1.0), (2, 0.92), (4, 0.81), (8, 0.62)))
CPU_TEST_ELASTICITY = AmdahlElasticity(serial=0.05)


@dataclasses.dataclass
class ActionTemplate:
    """Factory producing a fresh Action per invocation."""

    build: Callable[[str, str], Action]

    def make(self, task_id: str, traj_id: str) -> Action:
        return self.build(task_id, traj_id)


@dataclasses.dataclass
class TurnSpec:
    gen_s: float  # LLM generation time preceding the tool call(s)
    actions: List[ActionTemplate]


@dataclasses.dataclass
class TrajectorySpec:
    task_id: str
    traj_id: str
    arrival_s: float
    turns: List[TurnSpec]
    reward: List[ActionTemplate]
    memory_gb: float = 4.0


def _lognormal(rng: random.Random, median: float, sigma: float) -> float:
    return median * math.exp(rng.gauss(0.0, sigma))


# ---------------------------------------------------------------------------
# AI coding
# ---------------------------------------------------------------------------


def make_coding_workload(
    n_traj: int,
    seed: int = 0,
    turns_lo: int = 3,
    turns_hi: int = 10,
    tool_median_s: float = 1.2,
    gen_median_s: float = 4.0,
    reward_median_s: float = 30.0,
    reward_sigma: float = 1.0,  # heavy tail (paper: long-tailed test runs)
    arrival_spread_s: float = 10.0,
    task_id: str = "coding",
) -> List[TrajectorySpec]:
    rng = random.Random(seed)
    out = []
    for i in range(n_traj):
        turns = []
        for _ in range(rng.randint(turns_lo, turns_hi)):
            dur = _lognormal(rng, tool_median_s, 0.6)
            turns.append(
                TurnSpec(
                    gen_s=_lognormal(rng, gen_median_s, 0.4),
                    actions=[_cpu_tool(dur)],
                )
            )
        reward_dur = _lognormal(rng, reward_median_s, reward_sigma)
        out.append(
            TrajectorySpec(
                task_id=task_id,
                traj_id=f"{task_id}-{seed}-{i}",
                arrival_s=rng.uniform(0, arrival_spread_s),
                turns=turns,
                reward=[_cpu_reward(reward_dur)],
                memory_gb=rng.choice([2.0, 4.0, 8.0]),
            )
        )
    return out


def _cpu_tool(duration: float) -> ActionTemplate:
    def build(task_id: str, traj_id: str) -> Action:
        return Action(
            name="tool:exec",
            cost={"cpu": fixed("cpu", 1)},
            base_duration=duration,
            task_id=task_id,
            trajectory_id=traj_id,
        )

    return ActionTemplate(build)


def _cpu_reward(duration: float) -> ActionTemplate:
    def build(task_id: str, traj_id: str) -> Action:
        return Action(
            name="reward:tests",
            # discrete power-of-two DoPs (paper §4.1: "the allowed unit of
            # resource is discrete"); also bounds the DP option fan-out
            cost={"cpu": ResourceRequest("cpu", (1, 2, 4, 8, 16, 32))},
            key_resource="cpu",
            elasticity=CPU_TEST_ELASTICITY,
            base_duration=duration,
            task_id=task_id,
            trajectory_id=traj_id,
        )

    return ActionTemplate(build)


# ---------------------------------------------------------------------------
# DeepSearch
# ---------------------------------------------------------------------------


def make_deepsearch_workload(
    n_traj: int,
    seed: int = 0,
    turns_lo: int = 4,
    turns_hi: int = 12,
    api_median_s: float = 2.5,
    gen_median_s: float = 6.0,
    judge_median_s: float = 8.0,
    arrival_spread_s: float = 10.0,
    task_id: str = "deepsearch",
) -> List[TrajectorySpec]:
    rng = random.Random(seed + 1)
    out = []
    apis = ["google_search", "web_fetch", "web_fetch", "pdf_parse"]
    for i in range(n_traj):
        turns = []
        for _ in range(rng.randint(turns_lo, turns_hi)):
            api = rng.choice(apis)
            turns.append(
                TurnSpec(
                    gen_s=_lognormal(rng, gen_median_s, 0.4),
                    actions=[_api_call(api, _lognormal(rng, api_median_s, 0.5))],
                )
            )
        out.append(
            TrajectorySpec(
                task_id=task_id,
                traj_id=f"{task_id}-{seed}-{i}",
                arrival_s=rng.uniform(0, arrival_spread_s),
                turns=turns,
                reward=[_gpu_reward("judge", _lognormal(rng, judge_median_s, 0.5))],
                memory_gb=1.0,
            )
        )
    return out


def _api_call(api: str, duration: float) -> ActionTemplate:
    def build(task_id: str, traj_id: str) -> Action:
        return Action(
            name=f"tool:{api}",
            cost={api: fixed(api, 1)},
            base_duration=duration,
            task_id=task_id,
            trajectory_id=traj_id,
        )

    return ActionTemplate(build)


def _gpu_reward(service: str, duration: float) -> ActionTemplate:
    def build(task_id: str, traj_id: str) -> Action:
        return Action(
            name=f"reward:{service}",
            cost={"gpu": ResourceRequest("gpu", (1, 2, 4, 8))},
            key_resource="gpu",
            elasticity=GPU_ELASTICITY,
            base_duration=duration,
            service=service,
            task_id=task_id,
            trajectory_id=traj_id,
        )

    return ActionTemplate(build)


# ---------------------------------------------------------------------------
# MOPD (multi-teacher distillation)
# ---------------------------------------------------------------------------


def _zipf_sample(rng: random.Random, n: int, k: int, skew: float) -> List[int]:
    """Weighted sample of ``k`` distinct indices with Zipf(``skew``)
    popularity (paper Fig. 3d: per-service invocation counts vary by up
    to three orders of magnitude).  ``skew=0`` degenerates to uniform."""
    pool = list(range(n))
    weights = [1.0 / (t + 1) ** skew for t in pool]
    chosen: List[int] = []
    for _ in range(min(k, n)):
        total = sum(weights)
        r = rng.uniform(0, total)
        acc = 0.0
        for idx, w in enumerate(weights):
            acc += w
            if r <= acc:
                chosen.append(pool.pop(idx))
                weights.pop(idx)
                break
        else:  # pragma: no cover - float edge
            chosen.append(pool.pop())
            weights.pop()
    return chosen


def make_mopd_workload(
    n_traj: int,
    seed: int = 0,
    n_teachers: int = 9,
    gen_median_s: float = 12.0,
    teacher_median_s: float = 6.0,
    teachers_per_traj: int = 3,
    arrival_spread_s: float = 5.0,  # bursty: tight arrivals
    teacher_skew: float = 1.5,  # Zipf exponent over teacher popularity (Fig. 3d)
    task_id: str = "mopd",
) -> List[TrajectorySpec]:
    rng = random.Random(seed + 2)
    out = []
    for i in range(n_traj):
        # a single long generation phase, then a burst of teacher scoring
        turns = [TurnSpec(gen_s=_lognormal(rng, gen_median_s, 0.6), actions=[])]
        teachers = _zipf_sample(rng, n_teachers, teachers_per_traj, teacher_skew)
        reward = [
            _gpu_reward(f"teacher{t}", _lognormal(rng, teacher_median_s, 0.5))
            for t in teachers
        ]
        out.append(
            TrajectorySpec(
                task_id=task_id,
                traj_id=f"{task_id}-{seed}-{i}",
                arrival_s=rng.uniform(0, arrival_spread_s),
                turns=turns,
                reward=reward,
                memory_gb=1.0,
            )
        )
    return out


def workload_services(trajs: Sequence[TrajectorySpec]) -> List[str]:
    """All GPU service names a workload references (for EOE deployment)."""
    names = set()
    for t in trajs:
        for tmpl in t.reward:
            a = tmpl.make(t.task_id, t.traj_id)
            if a.service:
                names.add(a.service)
    return sorted(names)
