from repro.rl.rollout import RolloutRunner, StepStats
from repro.rl.tasks import (
    make_coding_workload,
    make_deepsearch_workload,
    make_mopd_workload,
    workload_services,
)

__all__ = [
    "RolloutRunner",
    "StepStats",
    "make_coding_workload",
    "make_deepsearch_workload",
    "make_mopd_workload",
    "workload_services",
]
