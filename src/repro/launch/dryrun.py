import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

The two lines above MUST stay the first statements in this module: jax
locks the device count at first init, and the production meshes need 512
placeholder host devices.  Nothing else in the repo sets this flag.

For each combination this produces a JSON record containing:
  * compile success + lower/compile wall time,
  * ``compiled.memory_analysis()`` (fits-per-device evidence),
  * ``compiled.cost_analysis()``  (per-device HLO FLOPs / bytes),
  * collective-op bytes parsed from the partitioned HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), per op kind,
  * analytic per-device bytes for params / optimizer / cache / batch,
  * the three roofline terms (§Roofline) and the dominant one.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
          --shape train_4k --mesh single --out results/dryrun
      PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import math
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, all_configs, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_shardings,
    decode_specs,
    input_shardings,
    input_specs,
    uses_sliding_window,
)
from repro.models.model import build_model
from repro.sharding.rules import make_rules
from repro.training.optimizer import AdamWConfig, abstract_adamw, adamw_state_specs
from repro.training.train_step import TrainState, make_train_step

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum result-operand bytes per collective kind from partitioned HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo.splitlines():
        stripped = line.lstrip()
        if "=" not in stripped:
            continue
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in stripped or f" {k}-start(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        lhs = stripped.split("=", 1)[1]
        op_idx = lhs.find(kind)
        shapes_part = lhs[:op_idx]
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        out["count"] += 1
    return out


def tree_bytes_per_device(abstract: Any, shardings: Any, mesh) -> float:
    """Analytic per-device bytes for a (ShapeDtypeStruct, spec) tree."""
    total = 0.0
    mesh_sizes = dict(mesh.shape)
    flat_a = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(shardings, is_leaf=lambda x: isinstance(x, (NamedSharding, P)))
    assert len(flat_a) == len(flat_s), (len(flat_a), len(flat_s))
    for aval, sh in zip(flat_a, flat_s):
        if aval is None:
            continue
        n = math.prod(aval.shape) if aval.shape else 1
        spec = sh.spec if isinstance(sh, NamedSharding) else sh
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh_sizes[a]
        total += n * aval.dtype.itemsize / shards
    return total


def _named(tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree,
        is_leaf=lambda x: isinstance(x, P) or x is None,
    )


def _lower(cfg, shape, mesh, rules, api) -> Tuple[Any, Dict[str, Any]]:
    """Build + lower the right step fn for this shape; returns (lowered, extras)."""
    n_dev = mesh.size
    params_abs = api.abstract_params()
    param_specs = api.param_specs(rules)
    extras: Dict[str, Any] = {}
    with mesh:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            state_abs = TrainState(params_abs, abstract_adamw(params_abs))
            state_specs = TrainState(param_specs, adamw_state_specs(param_specs))
            batch_abs = input_specs(cfg, shape)
            batch_specs = input_shardings(cfg, shape, rules)
            fn = make_train_step(api, opt_cfg, rules)
            metric_names = (
                ("loss", "lm_loss", "grad_norm", "lr")
                if cfg.family == "audio"
                else ("loss", "lm_loss", "load_balance", "router_z", "grad_norm", "lr")
            )
            metric_specs = {k: P() for k in metric_names}
            jitted = jax.jit(
                fn,
                in_shardings=(_named(state_specs, mesh), _named(batch_specs, mesh)),
                out_shardings=(_named(state_specs, mesh), _named(metric_specs, mesh)),
            )
            lowered = jitted.lower(state_abs, batch_abs)
            extras["state_bytes_per_dev"] = tree_bytes_per_device(state_abs, state_specs, mesh)
            extras["batch_bytes_per_dev"] = tree_bytes_per_device(batch_abs, batch_specs, mesh)
            tokens = shape.global_batch * (
                cfg.decoder_seq if cfg.family == "audio" else shape.seq_len
            )
            extras["model_flops"] = 6.0 * api.active_param_count() * tokens
        elif shape.kind == "prefill":
            batch_abs = input_specs(cfg, shape)
            batch_specs = input_shardings(cfg, shape, rules)
            fn = lambda p, b: api.prefill(p, b, rules)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(param_specs, mesh), _named(batch_specs, mesh)),
            )
            lowered = jitted.lower(params_abs, batch_abs)
            extras["state_bytes_per_dev"] = tree_bytes_per_device(params_abs, param_specs, mesh)
            extras["batch_bytes_per_dev"] = tree_bytes_per_device(batch_abs, batch_specs, mesh)
            extras["model_flops"] = (
                2.0 * api.active_param_count() * shape.global_batch * shape.seq_len
            )
        else:  # decode
            sw = cfg.sliding_window if uses_sliding_window(cfg, shape) else 0
            extras["sliding_window"] = sw
            state_abs, token_abs = decode_specs(api, shape)
            state_specs, token_spec = decode_shardings(api, shape, rules)
            fn = lambda p, s, t: api.decode_step(p, s, t, rules, sliding_window=sw)
            jitted = jax.jit(
                fn,
                in_shardings=(
                    _named(param_specs, mesh),
                    _named(state_specs, mesh),
                    NamedSharding(mesh, token_spec),
                ),
                out_shardings=(None, _named(state_specs, mesh)),
            )
            lowered = jitted.lower(params_abs, state_abs, token_abs)
            extras["state_bytes_per_dev"] = tree_bytes_per_device(
                params_abs, param_specs, mesh
            ) + tree_bytes_per_device(state_abs, state_specs, mesh)
            extras["model_flops"] = 2.0 * api.active_param_count() * shape.global_batch
    return lowered, extras


def _compiled_metrics(compiled) -> Dict[str, float]:
    out: Dict[str, float] = {}
    try:
        cost = compiled.cost_analysis()
        out["flops"] = float(cost.get("flops", 0.0))
        out["bytes"] = float(cost.get("bytes accessed", 0.0))
    except Exception:
        out["flops"] = out["bytes"] = 0.0
    col = collective_bytes(compiled.as_text())
    for k, v in col.items():
        out[f"col_{k}"] = v
    return out


def calibrate_layer_cost(
    cfg, shape, mesh, fsdp: bool
) -> Optional[Dict[str, float]]:
    """Per-layer in-scan cost via the U(2)-C(2) trick.

    ``compiled.cost_analysis`` counts a ``while`` body ONCE regardless of
    trip count (verified empirically), so scanned-layer cost is invisible.
    We compile a 2-layer variant twice — scanned C(2) and fully unrolled
    U(2) — and take ``body = U(2) - C(2)`` as the exact marginal cost of
    one additional layer trip.  ``true(L) = C(L) + (L-1) * body``.
    """
    import dataclasses

    repl = {"num_layers": 2, "scan_unroll": 1}
    if cfg.encoder_layers:
        repl["encoder_layers"] = 2
    cfg2 = dataclasses.replace(cfg, **repl)
    cfg2u = dataclasses.replace(cfg2, scan_unroll=2)
    rules = make_rules(mesh, fsdp=fsdp)
    try:
        lo_c, _ = _lower(cfg2, shape, mesh, rules, build_model(cfg2))
        m_c = _compiled_metrics(lo_c.compile())
        lo_u, _ = _lower(cfg2u, shape, mesh, rules, build_model(cfg2u))
        m_u = _compiled_metrics(lo_u.compile())
    except Exception:
        return None
    return {k: max(0.0, m_u.get(k, 0.0) - m_c.get(k, 0.0)) for k in m_u}


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    fsdp: Optional[bool] = None,
    remat: Optional[bool] = None,
    save_hlo: Optional[str] = None,
    calibrate: bool = True,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if remat is not None:
        import dataclasses

        cfg = dataclasses.replace(cfg, remat=remat)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    api = build_model(cfg)
    if fsdp is None:
        # FSDP when the replicated (model-sharded-only) train state would
        # not leave headroom on a 16 GB v5e chip.
        probe_rules = make_rules(mesh, fsdp=False)
        params_abs0 = api.abstract_params()
        state_bytes = tree_bytes_per_device(
            TrainState(params_abs0, abstract_adamw(params_abs0)),
            TrainState(api.param_specs(probe_rules),
                       adamw_state_specs(api.param_specs(probe_rules))),
            mesh,
        )
        fsdp = state_bytes > 11e9
    rules = make_rules(mesh, fsdp=fsdp)
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "fsdp": fsdp,
        "params": api.param_count(),
        "active_params": api.active_param_count(),
        "kind": shape.kind,
    }
    t0 = time.time()
    lowered, extras = _lower(cfg, shape, mesh, rules, api)
    rec.update(extras)
    rec["lower_s"] = time.time() - t0
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = time.time() - t1

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(mem, k)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # CPU backend may not support it
        rec["memory_analysis"] = {"error": str(e)}

    raw = _compiled_metrics(compiled)
    rec["cost_analysis_raw"] = raw
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())

    # ---- while-body trip-count correction (see calibrate_layer_cost) -----
    L = cfg.num_layers
    body = calibrate_layer_cost(cfg, shape, mesh, fsdp) if calibrate else None
    rec["layer_body_cost"] = body
    if body is not None:
        corrected = {k: raw.get(k, 0.0) + (L - 1) * body.get(k, 0.0) for k in raw}
    else:
        corrected = dict(raw)
    rec["cost_analysis"] = {
        "flops": corrected.get("flops", 0.0),
        "bytes_accessed": corrected.get("bytes", 0.0),
    }
    col = {
        k.removeprefix("col_"): v for k, v in corrected.items() if k.startswith("col_")
    }
    rec["collectives"] = col

    # ---- roofline terms (per-chip; §Roofline) ----------------------------
    flops_dev = corrected.get("flops", 0.0)
    bytes_dev = corrected.get("bytes", 0.0)
    col_bytes_dev = sum(v for k, v in col.items() if k != "count")
    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    collective_t = col_bytes_dev / ICI_BW
    rec["roofline"] = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "dominant": max(
            (("compute", compute_t), ("memory", memory_t), ("collective", collective_t)),
            key=lambda kv: kv[1],
        )[0],
        "model_flops_ratio": (
            rec.get("model_flops", 0.0) / (flops_dev * n_dev)
            if flops_dev > 0
            else None
        ),
    }
    rec["ok"] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*INPUT_SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--remat", default=None, choices=[None, "on", "off"])
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(all_configs()) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    fsdp = None if args.fsdp is None else args.fsdp == "on"
    remat = None if args.remat is None else args.remat == "on"

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tagsuf = f"_{args.tag}" if args.tag else ""
                name = f"{arch}_{shape}_{'multi' if mp else 'single'}{tagsuf}.json"
                path = os.path.join(args.out, name)
                if os.path.exists(path) and not args.tag:
                    print(f"skip {name} (exists)")
                    continue
                print(f"=== {arch} x {shape} x {'2x16x16' if mp else '16x16'} ===", flush=True)
                try:
                    rec = run_one(arch, shape, mp, fsdp=fsdp, remat=remat,
                                  save_hlo=args.save_hlo)
                except Exception as e:
                    import traceback

                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "ok": False, "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                if rec.get("ok"):
                    r = rec["roofline"]
                    print(
                        f"  ok lower={rec['lower_s']:.1f}s compile={rec['compile_s']:.1f}s "
                        f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
                        f"collective={r['collective_s']*1e3:.2f}ms dominant={r['dominant']}",
                        flush=True,
                    )
                else:
                    print(f"  FAILED: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
