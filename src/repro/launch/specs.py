"""ShapeDtypeStruct stand-ins for every model input (dry-run, no alloc).

``input_specs(cfg, shape)`` returns the abstract batch for train/prefill
kinds; decode kinds use ``decode_specs``.  ``input_shardings`` returns
the matching PartitionSpec tree.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import ModelApi
from repro.sharding.rules import Rules


def _tok(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract train/prefill batch for an assigned input shape."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        # seq_len applies to the (stub) encoder frames; decoder gets the
        # fixed text window (DESIGN.md §4).
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32),
            "tokens": _tok(B, cfg.decoder_seq),
        }
    if cfg.family == "vlm":
        return {
            "tokens": _tok(B, S - cfg.num_patches),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.float32
            ),
        }
    return {"tokens": _tok(B, S)}


def input_shardings(cfg: ModelConfig, shape: InputShape, rules: Rules) -> Dict[str, Any]:
    B = shape.global_batch
    batch_dims = ("batch",) if B % rules.data_extent == 0 else (None,)
    if cfg.family == "audio":
        return {
            "frames": rules.spec((B, shape.seq_len, cfg.d_model), (*batch_dims, None, None)),
            "tokens": rules.spec((B, cfg.decoder_seq), (*batch_dims, None)),
        }
    if cfg.family == "vlm":
        return {
            "tokens": rules.spec((B, shape.seq_len - cfg.num_patches), (*batch_dims, None)),
            "patch_embeds": rules.spec(
                (B, cfg.num_patches, cfg.d_model), (*batch_dims, None, None)
            ),
        }
    return {"tokens": rules.spec((B, shape.seq_len), (*batch_dims, None))}


def decode_specs(
    api: ModelApi, shape: InputShape
) -> Tuple[Any, jax.ShapeDtypeStruct]:
    """(abstract decode state, abstract one-token batch)."""
    B, S = shape.global_batch, shape.seq_len
    state = api.abstract_decode_state(B, S)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return state, token


def decode_shardings(api: ModelApi, shape: InputShape, rules: Rules):
    B, S = shape.global_batch, shape.seq_len
    state_specs = api.decode_state_specs(rules, B, S)
    tok_dims = ("batch", None) if B % rules.data_extent == 0 else (None, None)
    token_spec = rules.spec((B, 1), tok_dims)
    return state_specs, token_spec


def uses_sliding_window(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k decode on attention-bearing archs runs the sliding-window
    variant (sub-quadratic per brief); SSM archs decode natively."""
    return (
        shape.name == "long_500k"
        and cfg.family in ("dense", "moe", "vlm", "hybrid", "audio")
    )
