"""Serving launcher: batched prefill+decode requests against an arch.

``python -m repro.launch.serve --arch smollm-360m --requests 4 --new 16``
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--sliding-window", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import Engine, GenerationConfig

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = Engine(
        api,
        params,
        GenerationConfig(
            max_new_tokens=args.new,
            cache_len=args.prompt_len + args.new,
            sliding_window=args.sliding_window,
        ),
    )
    rng = np.random.default_rng(0)
    if cfg.family == "audio":
        batch = {
            "frames": jnp.asarray(
                rng.standard_normal((args.requests, 32, cfg.d_model), dtype=np.float32) * 0.02
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=(args.requests, args.prompt_len))
            ).astype(jnp.int32),
        }
    elif cfg.family == "vlm":
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=(args.requests, args.prompt_len))
            ).astype(jnp.int32),
            "patch_embeds": jnp.asarray(
                rng.standard_normal(
                    (args.requests, cfg.num_patches, cfg.d_model), dtype=np.float32
                )
                * 0.02
            ),
        }
    else:
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=(args.requests, args.prompt_len))
            ).astype(jnp.int32)
        }
    t0 = time.time()
    toks, logps = engine.generate(batch)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {toks.shape} in {dt:.1f}s "
          f"({args.requests*args.new/dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0][:8]))


if __name__ == "__main__":
    main()
