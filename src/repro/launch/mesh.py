"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Trivial 1x1 mesh on the real local device (smoke tests, examples)."""
    dev = jax.devices()[0]
    import numpy as np

    return jax.sharding.Mesh(np.array([[dev]]), axis_names=("data", "model"))
