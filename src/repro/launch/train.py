"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the *reduced* variant of any assigned
architecture on the synthetic stream (host mesh); on a real pod the same
entry point takes ``--full --mesh single|multi`` and runs the production
mesh with the dry-run's shardings.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.training import (
        AdamWConfig,
        DataConfig,
        MarkovTextStream,
        init_train_state,
        make_train_step,
        save_checkpoint,
    )

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    api = build_model(cfg)
    print(f"{cfg.name}: {api.param_count()/1e6:.1f}M params ({cfg.family})")

    state = init_train_state(api, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step = jax.jit(make_train_step(api, opt))

    rng = np.random.default_rng(0)
    stream = MarkovTextStream(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=0)
    )
    t0 = time.time()
    for i, raw in zip(range(args.steps), stream):
        toks = jnp.asarray(raw["tokens"][:, : args.seq])
        if cfg.family == "audio":
            batch = {
                "frames": jnp.asarray(
                    rng.standard_normal((args.batch, 32, cfg.d_model), dtype=np.float32) * 0.02
                ),
                "tokens": toks[:, :16],
            }
        elif cfg.family == "vlm":
            batch = {
                "tokens": toks,
                "patch_embeds": jnp.asarray(
                    rng.standard_normal(
                        (args.batch, cfg.num_patches, cfg.d_model), dtype=np.float32
                    )
                    * 0.02
                ),
            }
        else:
            batch = {"tokens": toks}
        state, m = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(
                f"step {i:4d} loss {float(m['loss']):.3f} "
                f"({(time.time()-t0)/(i+1):.2f}s/step)",
                flush=True,
            )
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
