"""Logical-axis -> physical-mesh sharding rules.

Production meshes (launch/mesh.py): ``(data=16, model=16)`` single-pod,
``(pod=2, data=16, model=16)`` multi-pod.  Logical rules:

* ``batch``     -> all data-parallel axes (``pod`` + ``data``);
* ``heads`` / ``mlp`` / ``vocab`` / ``expert`` -> ``model`` (tensor /
  expert parallelism);
* ``capacity``  -> data axes (the MoE dispatch buffer is co-sharded with
  tokens so GSPMD emits the expert all-to-all);
* ``embed``     -> ``data`` when FSDP is on (params sharded within a pod,
  replicated across pods — multi-pod FSDP would put optimizer-state
  gathers on the slow cross-pod links);
* ``kv_seq``    -> data axes for the long-context decode caches.

Every rule silently falls back to replication when the dimension is not
divisible by the mesh-axis extent (e.g. granite's 24 heads or smollm's
15 heads on a 16-way model axis — the FFN still shards; see DESIGN.md).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Rules:
    mesh: Mesh
    mapping: Dict[str, Tuple[str, ...]]
    axis_sizes: Dict[str, int]

    def spec(self, shape: Sequence[int], dims: Sequence[Optional[str]]) -> P:
        """PartitionSpec for ``shape`` with logical ``dims`` labels."""
        assert len(shape) == len(dims), f"{shape} vs {dims}"
        used: set = set()
        out = []
        for size, dim in zip(shape, dims):
            axes = self.mapping.get(dim or "", ())
            axes = tuple(a for a in axes if a not in used)
            extent = math.prod(self.axis_sizes[a] for a in axes) if axes else 1
            if axes and size % extent == 0 and size >= extent:
                out.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                out.append(None)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, shape: Sequence[int], dims: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, dims))

    def constrain(self, x: jax.Array, dims: Sequence[Optional[str]]) -> jax.Array:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, dims))
        )

    def extent(self, dim: str) -> int:
        """Total mesh extent the logical ``dim`` maps onto (1 if unmapped)."""
        axes = self.mapping.get(dim, ())
        return math.prod(self.axis_sizes[a] for a in axes) if axes else 1

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return self.mapping["batch"]

    @property
    def data_extent(self) -> int:
        return math.prod(self.axis_sizes[a] for a in self.data_axes)


def make_rules(mesh: Mesh, fsdp: bool = False, seq_shard: bool = False) -> Rules:
    axes = mesh.axis_names
    data_axes: Tuple[str, ...] = (
        ("pod", "data") if "pod" in axes else ("data",)
    )
    mapping: Dict[str, Tuple[str, ...]] = {
        "batch": data_axes,
        "capacity": data_axes,
        "kv_seq": data_axes,
        "seq": data_axes if seq_shard else (),
        "heads": ("model",),
        "kv_heads": ("model",),
        # decode KV caches: sequence sharded over the model axis (batch
        # occupies data).  Avoids sub-axis kv x hd splits entirely; the
        # attention's softmax/output reductions over the sharded S are
        # KB-scale vs the 100 MB/layer cache re-gathers any head-dim
        # sharding forces for GQA (EXPERIMENTS.md §Perf iteration 7).
        "cache_seq": ("model",),
        "qkv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "ssm_inner": ("model",),
        "embed": ("data",) if fsdp else (),
        "embed_tp": ("model",),  # activations' d_model inside TP regions
        "layers": (),
        "head_dim": (),
        "ssm_state": (),
        "": (),
    }
    return Rules(mesh=mesh, mapping=mapping, axis_sizes=dict(mesh.shape))


def single_device_rules() -> Rules:
    """Rules over the trivial 1-device mesh (tests / smoke runs)."""
    dev = jax.devices()[0]
    mesh = Mesh([[dev]], axis_names=("data", "model"))
    return make_rules(mesh)
