from repro.sharding.rules import Rules, make_rules

__all__ = ["Rules", "make_rules"]
