"""Flash attention Pallas TPU kernel (causal, GQA).

TPU adaptation: query blocks ride the grid's minor dimension so the MXU
sees [block_q, d] x [d, block_k] matmuls; K/V live in VMEM per
(batch, kv-head) and the kernel walks k-blocks with an online-softmax
running (max, sum, acc) held in VMEM scratch.  Block sizes default to
MXU-aligned 128.

Layout: q [B, H, S, d], k/v [B, KV, S, d] -> out [B, H, S, d].
Grid: (B, H, S // block_q); GQA maps query head h to kv head h // g.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [block_q, d]
    k_ref,  # [S, d]  (whole K for this (b, kv-head))
    v_ref,  # [S, d]
    o_ref,  # [block_q, d]
    *,
    block_q: int,
    block_k: int,
    seq_len: int,
    causal: bool,
):
    qb = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)  # [bq, d]
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    nk = seq_len // block_k
    # causal: k-blocks strictly after this q-block contribute nothing
    nk_needed = (
        jax.lax.div((qb + 1) * block_q + block_k - 1, block_k) if causal else nk
    )

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T * scale  # [bq, bk]
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk_needed, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, H, S, d]
    k: jax.Array,  # [B, KV, S, d]
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, d = q.shape
    KV = k.shape[1]
    g = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)

    grid = (B, H, S // block_q)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_len=S,
        causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, S, d), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((None, None, S, d), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
