"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's exact contract (same arguments, same
output shapes/dtypes); kernel tests sweep shapes/dtypes and
``assert_allclose`` against these.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # [B, H, S, d]
    k: jax.Array,  # [B, KV, S, d]
    v: jax.Array,  # [B, KV, S, d]
    causal: bool = True,
) -> jax.Array:
    B, H, S, d = q.shape
    KV = k.shape[1]
    g = H // KV
    qg = q.reshape(B, KV, g, S, d)
    scores = jnp.einsum(
        "bngqd,bnkd->bngqk", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bnkd->bngqd", w.astype(v.dtype), v)
    return out.reshape(B, H, S, d)


def ssd_chunk_ref(
    x: jax.Array,  # [Q, hd]   (dt-weighted inputs for ONE (batch, chunk, head))
    b: jax.Array,  # [Q, N]
    c: jax.Array,  # [Q, N]
    cum: jax.Array,  # [Q]     inclusive cumsum of dA within the chunk
) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD: returns (y_intra [Q, hd], chunk_state [hd, N])."""
    Q = x.shape[0]
    diff = cum[:, None] - cum[None, :]  # [Q, Q]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    cb = (c.astype(jnp.float32) @ b.astype(jnp.float32).T) * L  # [Q, Q]
    y = (cb @ x.astype(jnp.float32)).astype(x.dtype)
    decay_to_end = jnp.exp(cum[-1] - cum)  # [Q]
    state = jnp.einsum(
        "qd,qm,q->dm", x.astype(jnp.float32), b.astype(jnp.float32), decay_to_end
    )
    return y, state


def moe_matmul_ref(
    buf: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
) -> jax.Array:
    return jnp.einsum("ecd,edf->ecf", buf, w, preferred_element_type=jnp.float32).astype(
        buf.dtype
    )


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * weight
