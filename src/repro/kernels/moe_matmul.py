"""Grouped (per-expert) matmul Pallas TPU kernel.

The MoE dispatch packs tokens into an ``[E, C, D]`` buffer; each expert
then runs its own ``[C, D] x [D, F]`` matmul.  The kernel grids over
(expert, C-block, F-block) with a D-block accumulation loop — block
shapes default to the 128-aligned MXU tile so each VMEM-resident tile is
(bc x bd) + (bd x bf) + (bc x bf) f32 <= ~a few hundred KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_db: int):
    db = pl.program_id(3)

    @pl.when(db == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # [bc, bd]
    w = w_ref[...].astype(jnp.float32)  # [bd, bf]
    acc_ref[...] += x @ w

    @pl.when(db == n_db - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_matmul(
    buf: jax.Array,  # [E, C, D]
    w: jax.Array,  # [E, D, F]
    *,
    block_c: int = 128,
    block_d: int = 128,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    E, C, D = buf.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)
    assert C % block_c == 0 and D % block_d == 0 and F % block_f == 0
    n_db = D // block_d
    grid = (E, C // block_c, F // block_f, n_db)
    return pl.pallas_call(
        functools.partial(_moe_kernel, n_db=n_db),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_c, block_d), lambda e, i, j, kd: (e, i, kd)),
            pl.BlockSpec((None, block_d, block_f), lambda e, i, j, kd: (e, kd, j)),
        ],
        out_specs=pl.BlockSpec((None, block_c, block_f), lambda e, i, j, kd: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(buf, w)
