"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

Computes, for one (batch, chunk, head) grid cell, the quadratic
intra-chunk output and the chunk's contribution to the inter-chunk
state (the sequential inter-chunk recurrence stays a cheap lax.scan in
:mod:`repro.models.ssm` — it is O(S/Q) steps over tiny states).

VMEM tiling: the [Q, Q] decay mask is materialized per head in VMEM
(Q = 256 -> 256 KB f32), never in HBM — on GPU the reference
implementation tiles over the same quadratic form with shared memory;
the TPU-native adaptation keeps one chunk resident and lets the MXU
run the [Q,N]x[N,Q] and [Q,Q]x[Q,hd] contractions.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, b_ref, c_ref, cum_ref, y_ref, state_ref):
    x = x_ref[...].astype(jnp.float32)  # [Q, hd] (dt-weighted inputs)
    b = b_ref[...].astype(jnp.float32)  # [Q, N]
    c = c_ref[...].astype(jnp.float32)  # [Q, N]
    cum = cum_ref[...].astype(jnp.float32)  # [Q]
    Q = x.shape[0]
    diff = cum[:, None] - cum[None, :]  # [Q, Q]
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(row >= col, jnp.exp(diff), 0.0)
    cb = (c @ b.T) * L  # [Q, Q]
    y_ref[...] = (cb @ x).astype(y_ref.dtype)
    decay_to_end = jnp.exp(cum[-1] - cum)  # [Q]
    state_ref[...] = ((x * decay_to_end[:, None]).T @ b).astype(state_ref.dtype)


def ssd_intra_chunk(
    x: jax.Array,  # [BNC, H, Q, hd]  dt-weighted inputs per chunk
    b: jax.Array,  # [BNC, Q, N]
    c: jax.Array,  # [BNC, Q, N]
    cum: jax.Array,  # [BNC, H, Q]
    *,
    interpret: bool = False,
):
    """Returns (y_intra [BNC, H, Q, hd], states [BNC, H, hd, N])."""
    BNC, H, Q, hd = x.shape
    N = b.shape[-1]
    grid = (BNC, H)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, Q, hd), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((None, Q, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((None, Q, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((None, None, Q), lambda i, h: (i, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Q, hd), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((None, None, hd, N), lambda i, h: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BNC, H, Q, hd), x.dtype),
            jax.ShapeDtypeStruct((BNC, H, hd, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, cum)
