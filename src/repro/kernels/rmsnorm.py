"""Fused RMSNorm Pallas TPU kernel (row-blocked, single HBM round-trip)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype)) * w_ref[...]


def rmsnorm(
    x: jax.Array,  # [T, D] (callers flatten leading dims)
    weight: jax.Array,  # [D]
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    T, D = x.shape
    block_rows = min(block_rows, T)
    assert T % block_rows == 0, (T, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
    )(x, weight)
