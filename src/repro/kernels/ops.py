"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` (default off) runs the kernel bodies in Python on CPU
— the validation mode used by this repo's tests; on real TPUs the same
calls compile to Mosaic.  ``use_pallas(cfg)`` gates kernel usage so CPU
smoke tests and the dry-run keep using the XLA reference path.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_matmul import moe_matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_scan import ssd_intra_chunk


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_op(q, k, v, *, causal=True, block_q=128, block_k=128, interpret=False):
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )


@partial(jax.jit, static_argnames=("block_c", "block_d", "block_f", "interpret"))
def moe_matmul_op(buf, w, *, block_c=128, block_d=128, block_f=128, interpret=False):
    return moe_matmul(
        buf, w, block_c=block_c, block_d=block_d, block_f=block_f, interpret=interpret
    )


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_op(x, weight, *, eps=1e-5, block_rows=256, interpret=False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = rmsnorm(x2, weight, eps=eps, block_rows=min(block_rows, x2.shape[0]),
                  interpret=interpret)
    return out.reshape(shape)


@partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_op(x, b, c, cum, *, interpret=False):
    return ssd_intra_chunk(x, b, c, cum, interpret=interpret)
