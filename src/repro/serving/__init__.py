from repro.serving.engine import Engine, GenerationConfig
from repro.serving.reward_service import RewardService, deploy_reward_service

__all__ = ["Engine", "GenerationConfig", "RewardService", "deploy_reward_service"]
