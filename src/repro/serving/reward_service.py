"""Reward services: real JAX models deployed behind the GPU manager.

The paper's MOPD workload serves many teacher models whose SM activity
averages <3% (§2.2 Fig. 3b) — the motivating waste.  Here each service
is an :class:`~repro.serving.engine.Engine` over a (small) model; the
GPU manager's EOE decides which service is resident on which chunk, and
the profiled DoP scaling supplies the action's elasticity table.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.action import TableElasticity
from repro.core.managers.gpu import ServiceSpec
from repro.models.model import build_model
from repro.serving.engine import Engine, GenerationConfig


@dataclasses.dataclass
class RewardService:
    """A deployable scoring service (LLM-as-judge / teacher log-prob)."""

    name: str
    cfg: ModelConfig
    engine: Engine
    state_gb: float

    def score(self, tokens: jnp.ndarray) -> jnp.ndarray:
        return self.engine.score({"tokens": tokens})

    def spec(self) -> ServiceSpec:
        return ServiceSpec(self.name, self.state_gb, dops=(1, 2, 4, 8))

    # -- profiled elasticity (paper §4.1: profiled in advance) --------------
    @staticmethod
    def profiled_elasticity() -> TableElasticity:
        """TP scaling efficiency measured on teacher-model inference."""
        return TableElasticity(table=((1, 1.0), (2, 0.92), (4, 0.81), (8, 0.62)))


def deploy_reward_service(
    name: str, cfg: ModelConfig, key: Optional[jax.Array] = None
) -> RewardService:
    api = build_model(cfg)
    params = api.init(key if key is not None else jax.random.PRNGKey(hash(name) % 2**31))
    engine = Engine(api, params, GenerationConfig(max_new_tokens=8, cache_len=128))
    n_params = api.param_count()
    state_gb = n_params * 2 / 1e9  # bf16 weights
    return RewardService(name=name, cfg=cfg, engine=engine, state_gb=max(0.5, state_gb))
