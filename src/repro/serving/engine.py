"""Serving engine: batched prefill + autoregressive decode.

Used both by the examples (serve a small model with batched requests)
and by the GPU manager's reward services (rl/ + serving/reward_service).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import ModelApi
from repro.sharding.rules import Rules


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    cache_len: int = 512
    sliding_window: int = 0


class Engine:
    """Compiles prefill/decode once per (batch, cache_len) signature."""

    def __init__(self, api: ModelApi, params, gen: GenerationConfig, rules: Optional[Rules] = None):
        self.api = api
        self.params = params
        self.gen = gen
        self.rules = rules
        self._prefill = jax.jit(lambda p, b: api.prefill(p, b, rules))
        self._decode = jax.jit(
            lambda p, s, t: api.decode_step(
                p, s, t, rules, sliding_window=gen.sliding_window
            )
        )

    def generate(
        self, batch: Dict[str, jax.Array], key: Optional[jax.Array] = None
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (generated tokens [B, max_new], per-step logprobs)."""
        logits, state = self._prefill(self.params, batch)
        B = logits.shape[0]
        out_toks = []
        out_logps = []
        key = key if key is not None else jax.random.PRNGKey(0)
        for i in range(self.gen.max_new_tokens):
            if self.gen.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / self.gen.temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            logp = jax.nn.log_softmax(logits, axis=-1)
            out_logps.append(jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0])
            tok = tok[:, None].astype(jnp.int32)
            out_toks.append(tok)
            logits, state = self._decode(self.params, state, tok)
        return jnp.concatenate(out_toks, axis=1), jnp.stack(out_logps, axis=1)

    def score(self, batch: Dict[str, jax.Array]) -> jnp.ndarray:
        """Sequence log-likelihood (used by LLM-as-judge reward services)."""
        from repro.training.grpo import token_logprobs

        logp = token_logprobs(self.params, batch["tokens"], self.api, self.rules)
        mask = batch.get("mask")
        if mask is not None:
            return jnp.sum(logp * mask, axis=-1)
        return jnp.sum(logp, axis=-1)
